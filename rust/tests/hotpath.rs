//! Hot-path integration tests: the buffer-resident step path must be
//! bit-identical to the literal path, a buffer-path training step must
//! move nothing but batch + scalars across the host boundary, the
//! literal-resident accumulate loop must produce the same mean gradient
//! as the legacy host-summing path, and the prefetch pipeline must
//! deliver exactly the synchronous batcher's sequence.
//!
//! Tests needing compiled programs skip silently when `artifacts/tiny`
//! is absent (run `make artifacts` first); the pipeline tests are pure.

use std::path::PathBuf;

use revffn::data::synthetic::{Corpus, CorpusConfig};
use revffn::data::{encode_corpus, Batcher, Pipeline, Tokenizer};
use revffn::runtime::literal::to_f32_vec;
use revffn::runtime::{Artifact, Batch, Device, GradAccumulator, ProgramCache, Stepper};

fn artifacts_root() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    p.join("index.json").exists().then_some(p)
}

/// Stepper + two deterministic batches for the revffn_stage2 variant.
fn stage2_fixture(device: &Device, cache: &ProgramCache) -> Option<(Stepper, Vec<Batch>)> {
    let root = artifacts_root()?;
    let artifact = Artifact::load(root.join("revffn_stage2")).ok()?;
    let stepper = Stepper::new(device, cache, artifact).ok()?;
    if !stepper.supports_accumulation() {
        return None;
    }
    let (b, s) = stepper.batch_shape();
    let corpus = Corpus::generate(CorpusConfig { n_train: 64, ..Default::default() });
    let tokenizer = Tokenizer::train(&corpus.train_text(), stepper.vocab_size()).ok()?;
    let samples = encode_corpus(&tokenizer, &corpus.train, s);
    let mut batcher = Batcher::new(samples, b, s, 3);
    let batches = (0..2).map(|_| batcher.next_batch()).collect();
    Some((stepper, batches))
}

#[test]
fn accumulate_literal_path_matches_host_summing() {
    let device = Device::cpu().unwrap();
    let cache = ProgramCache::new();
    let Some((stepper, batches)) = stage2_fixture(&device, &cache) else { return };

    // literal-resident path: gradients never materialized on host until
    // this test downloads the final mean for comparison
    let mut acc = GradAccumulator::for_stepper(&stepper);
    for batch in &batches {
        acc.add(stepper.grad_step_literals(batch).unwrap().grads).unwrap();
    }
    assert_eq!(acc.count(), 2);
    let mean_lits = acc.finish().unwrap();
    let mean_dev: Vec<Vec<f32>> =
        mean_lits.iter().map(|l| to_f32_vec(l).unwrap()).collect();

    // legacy host-summing path over the SAME batches
    let mut host_sum: Option<Vec<Vec<f32>>> = None;
    for batch in &batches {
        let (g, _loss, _aux) = stepper.grad_step(batch).unwrap();
        match host_sum.as_mut() {
            None => host_sum = Some(g),
            Some(acc) => {
                for (a, gi) in acc.iter_mut().zip(&g) {
                    for (x, y) in a.iter_mut().zip(gi) {
                        *x += *y;
                    }
                }
            }
        }
    }
    let mut host_mean = host_sum.unwrap();
    for g in host_mean.iter_mut() {
        for x in g.iter_mut() {
            *x *= 0.5;
        }
    }

    assert_eq!(mean_dev.len(), host_mean.len());
    for (td, (d, h)) in mean_dev.iter().zip(&host_mean).enumerate() {
        assert_eq!(d.len(), h.len(), "tensor {td} length");
        for (i, (x, y)) in d.iter().zip(h).enumerate() {
            assert!(
                (x - y).abs() <= 1e-5 + 1e-4 * y.abs(),
                "tensor {td} elem {i}: device {x} vs host {y}"
            );
        }
    }
}

#[test]
fn forced_host_fallback_matches_device_accumulator() {
    let device = Device::cpu().unwrap();
    let cache = ProgramCache::new();
    let Some((stepper, batches)) = stage2_fixture(&device, &cache) else { return };

    let mut dev_acc = GradAccumulator::for_stepper(&stepper);
    // fallback accumulator: no compiled accum/scale pair
    let mut host_acc = GradAccumulator::new(None, None, stepper.trainable_shapes());
    assert!(!host_acc.is_device_resident());

    // two optimizer steps through the SAME recycled accumulators — the
    // second exercises buffer reuse after finish()
    for _ in 0..2 {
        for batch in &batches {
            dev_acc.add(stepper.grad_step_literals(batch).unwrap().grads).unwrap();
            host_acc.add(stepper.grad_step_literals(batch).unwrap().grads).unwrap();
        }
        let dev = dev_acc.finish().unwrap();
        let host = host_acc.finish().unwrap();
        assert_eq!(dev_acc.count(), 0);
        for (d_lit, h_lit) in dev.iter().zip(&host) {
            let d = to_f32_vec(d_lit).unwrap();
            let h = to_f32_vec(h_lit).unwrap();
            for (x, y) in d.iter().zip(&h) {
                assert!((x - y).abs() <= 1e-5 + 1e-4 * y.abs());
            }
        }
    }
}

#[test]
fn accumulate_grad_norm_comparable_to_fused_steps() {
    let device = Device::cpu().unwrap();
    let cache = ProgramCache::new();
    let Some((mut stepper_a, batches)) = stage2_fixture(&device, &cache) else { return };

    // grad_accum=2, literal-resident: one update on the mean gradient
    let mut acc = GradAccumulator::for_stepper(&stepper_a);
    for batch in &batches {
        acc.add(stepper_a.grad_step_literals(batch).unwrap().grads).unwrap();
    }
    let mean = acc.finish().unwrap();
    let (gn_accum, _t) = stepper_a.apply_accumulated(&mean, 1e-4).unwrap();

    // two fused steps over the same batches (params drift by one tiny
    // update between them, and per-microbatch norms average >= the
    // mean-gradient norm, so the comparison is a band, not an equality)
    let (mut stepper_b, _) = stage2_fixture(&device, &cache).unwrap();
    let mut gn_sum = 0.0f32;
    for batch in &batches {
        gn_sum += stepper_b.train_step(batch, 1e-4).unwrap().grad_norm;
    }
    let gn_fused = gn_sum / 2.0;

    assert!(gn_accum.is_finite() && gn_accum >= 0.0);
    assert!(
        gn_accum <= gn_fused * 1.5 + 1e-3,
        "mean-gradient norm {gn_accum} should not exceed the averaged per-batch norms {gn_fused}"
    );
    assert!(
        gn_accum >= gn_fused * 0.2 - 1e-3,
        "mean-gradient norm {gn_accum} collapsed vs per-batch norms {gn_fused}"
    );
}

/// The buffer-resident fused path must match the literal path exactly:
/// same compiled program, same values, same device — so loss,
/// grad-norm, and post-step parameters are bit-identical. Also pins
/// that lazy snapshots (`materialize_params`, i.e.
/// `DeviceState::to_literals`) and eval on the buffer path agree with
/// the literal world.
#[test]
fn buffer_fused_path_matches_literal_path_bitwise() {
    let device = Device::cpu().unwrap();
    let cache = ProgramCache::new();
    let Some((mut lit, batches)) = stage2_fixture(&device, &cache) else { return };
    let (mut buf, _) = stage2_fixture(&device, &cache).unwrap();
    if buf.enable_device_state().is_err() {
        return; // upload unsupported on this runtime — nothing to compare
    }

    for round in 0..3 {
        let batch = &batches[round % batches.len()];
        let a = lit.train_step(batch, 1e-4).unwrap();
        let b = buf.train_step(batch, 1e-4).unwrap();
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "round {round}: loss {} vs {}",
            a.loss,
            b.loss
        );
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "round {round}: grad_norm {} vs {}",
            a.grad_norm,
            b.grad_norm
        );
        assert_eq!(a.router_aux.to_bits(), b.router_aux.to_bits(), "round {round}: aux");
    }

    // eval on the two paths sees the same model
    let (el, _) = lit.eval_step(&batches[0]).unwrap();
    let (eb, _) = buf.eval_step(&batches[0]).unwrap();
    assert_eq!(el.to_bits(), eb.to_bits(), "eval loss diverged");

    // lazy snapshot: buffers -> literals -> host mirror, then compare
    // every tensor exactly
    let pl = lit.materialize_params().unwrap();
    let pb = buf.materialize_params().unwrap();
    assert_eq!(pl.len(), pb.len());
    for ((name, _, a), (_, _, b)) in pl.snapshot().zip(pb.snapshot()) {
        assert_eq!(a, b, "post-step params diverged at {name}");
    }
}

/// A buffer-path training step performs no host staging of params or
/// moments: exactly the batch (tokens/targets/mask) + lr + step scalars
/// go up, exactly the loss/grad-norm/aux scalars come down.
#[test]
fn buffer_path_moves_only_batch_and_scalars() {
    let device = Device::cpu().unwrap();
    let cache = ProgramCache::new();
    let Some((mut stepper, batches)) = stage2_fixture(&device, &cache) else { return };
    if stepper.enable_device_state().is_err() {
        return;
    }
    // first step verifies the buffer path (or falls back)
    stepper.train_step(&batches[0], 1e-4).unwrap();
    if !stepper.is_device_resident() {
        return; // runtime fell back — the parity test still covers it
    }
    let before = device.transfer_stats();
    let steps = 2u64;
    for i in 0..steps as usize {
        stepper.train_step(&batches[i % batches.len()], 1e-4).unwrap();
    }
    let moved = device.transfer_stats().since(&before);
    assert_eq!(moved.uploads, steps * 5, "uploads: batch(3) + lr + step only");
    assert_eq!(moved.downloads, steps * 3, "downloads: loss + grad-norm + aux only");
}

/// The fully buffer-resident accumulate loop (grad → accum → scale →
/// apply, all on `PjRtBuffer`s) must match the literal accumulate loop
/// bit for bit.
#[test]
fn accum_buffer_loop_matches_literal_accum_loop() {
    let device = Device::cpu().unwrap();
    let cache = ProgramCache::new();
    let Some((mut lit, batches)) = stage2_fixture(&device, &cache) else { return };
    if !lit.supports_device_accum() {
        return;
    }
    let (mut buf, _) = stage2_fixture(&device, &cache).unwrap();
    if buf.enable_device_state().is_err() {
        return;
    }

    // literal loop
    let mut acc_l = GradAccumulator::for_stepper(&lit);
    for batch in &batches {
        acc_l.add(lit.grad_step_literals(batch).unwrap().grads).unwrap();
    }
    let mean_l = acc_l.finish().unwrap();
    let (gn_l, _) = lit.apply_accumulated(&mean_l, 1e-4).unwrap();

    // buffer loop over the SAME batches
    let mut acc_b = GradAccumulator::for_stepper(&buf);
    assert!(acc_b.supports_buffers());
    let mut ok = true;
    for batch in &batches {
        match buf.grad_step_buffers(batch) {
            Ok(out) => acc_b.add_buffers(out.grads).unwrap(),
            Err(_) if buf.can_abandon_buffers() => {
                ok = false; // runtime cannot untuple buffers — skip
                break;
            }
            Err(e) => panic!("grad_step_buffers: {e}"),
        }
    }
    if !ok {
        return;
    }
    let mean_b = acc_b.finish_buffers().unwrap();
    let (gn_b, _) = buf.apply_accumulated_buffers(&mean_b, 1e-4).unwrap();

    assert_eq!(gn_l.to_bits(), gn_b.to_bits(), "grad norm {gn_l} vs {gn_b}");
    let pl = lit.materialize_params().unwrap();
    let pb = buf.materialize_params().unwrap();
    for ((name, _, a), (_, _, b)) in pl.snapshot().zip(pb.snapshot()) {
        assert_eq!(a, b, "post-apply params diverged at {name}");
    }
}

/// Artifact sets without the compiled accum_step/scale pair cannot run
/// buffer-path accumulation: the accumulator reports it, add_buffers
/// refuses, and — as the engine does — abandoning the pinned buffers
/// drops cleanly back to the (still current) literal path.
#[test]
fn accum_without_compiled_pair_falls_back_to_literals() {
    let device = Device::cpu().unwrap();
    let cache = ProgramCache::new();
    let Some((mut stepper, batches)) = stage2_fixture(&device, &cache) else { return };

    // accumulator shaped like one for an old artifact set (no pair)
    let mut old = GradAccumulator::new(None, None, stepper.trainable_shapes());
    assert!(!old.supports_buffers());
    assert!(!old.is_device_resident());

    if stepper.enable_device_state().is_err() {
        return;
    }
    // the engine's open_phase/train_one fallback: buffers are still
    // abandonable (no buffer step ran), then the literal loop works
    assert!(stepper.can_abandon_buffers());
    stepper.abandon_buffers().unwrap();
    assert!(!stepper.is_device_resident());

    for batch in &batches {
        old.add(stepper.grad_step_literals(batch).unwrap().grads).unwrap();
    }
    let mean = old.finish().unwrap();
    let (gn, _) = stepper.apply_accumulated(&mean, 1e-4).unwrap();
    assert!(gn.is_finite());
}

#[test]
fn pipeline_delivers_synchronous_sequence_on_real_corpus() {
    // pure (no artifacts): the prefetch pipeline over an encoded corpus
    // must be bit-identical to the synchronous batcher with the same seed
    let corpus = Corpus::generate(CorpusConfig { n_train: 48, ..Default::default() });
    let tokenizer = Tokenizer::train(&corpus.train_text(), 256).unwrap();
    let samples = encode_corpus(&tokenizer, &corpus.train, 32);
    assert!(!samples.is_empty());

    let mut sync = Batcher::new(samples.clone(), 4, 32, 11);
    let mut pipe = Pipeline::spawn(Batcher::new(samples, 4, 32, 11));
    for _ in 0..3 * 12 {
        // several epochs worth, so reshuffles are covered too
        let got = pipe.next_batch().unwrap();
        let want = sync.next_batch();
        assert_eq!(got.tokens, want.tokens);
        assert_eq!(got.targets, want.targets);
        assert_eq!(got.loss_mask, want.loss_mask);
        pipe.recycle(got);
    }
}
