//! Serve-subsystem integration tests over the real AOT artifacts:
//! concurrent jobs must interleave deterministically on one shared
//! device and finish with losses bit-identical to running each job
//! solo; admission must order the waiting queue by (class, tenant
//! debt, deadline, submit order) and admit as budget frees; tenant
//! quotas must hold one tenant without blocking others; the TCP
//! control plane must speak the NDJSON protocol — including keyset
//! cursor pagination — end to end.
//!
//! Like the other integration tests, everything skips silently when
//! `artifacts/tiny` is absent (run `make artifacts` first).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};

use revffn::config::{PriceGeometry, RunConfig, ServeConfig};
use revffn::coordinator::Trainer;
use revffn::engine::Method;
use revffn::runtime::Device;
use revffn::serve::protocol::{JobState, Priority, Request};
use revffn::serve::{admission, Scheduler, SubmitMeta};
use revffn::util::json::{self, Json};
use revffn::util::ScratchDir;

fn artifacts_root() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    p.join("index.json").exists().then_some(p)
}

/// A short run of `method` (pre-pass off, eval only at stage ends).
fn job_cfg(root: &Path, out: &Path, method: Method) -> RunConfig {
    let mut cfg = RunConfig::default_tiny(root);
    cfg.method = method;
    cfg.schedule.stage1_steps = if method.is_two_stage() { 2 } else { 0 };
    cfg.schedule.stage2_steps = 3;
    cfg.schedule.warmup_steps = 1;
    cfg.data.pretrain_steps = 0;
    cfg.data.n_train = 48;
    cfg.data.n_eval = 16;
    cfg.eval_every = 0;
    cfg.eval_batches = 1;
    cfg.out_dir = out.into();
    cfg
}

fn serve_opts(root: &Path, scratch: &Path, budget_gb: f64, quantum: u64) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        artifacts: root.to_path_buf(),
        budget_gb,
        quantum,
        assumptions: "f32".into(),
        price_geometry: PriceGeometry::Manifest,
        run_root: scratch.join("serve"),
        // tests manage checkpoints explicitly per-job
        checkpoint_every: 0,
        recover: false,
        ..ServeConfig::default()
    }
}

/// (type, step, loss-bits) triples of a job's step events — the
/// deterministic projection (wall-clock fields excluded).
fn step_signature(events: &[String]) -> Vec<(String, u64, u32)> {
    events
        .iter()
        .map(|l| json::parse(l).unwrap())
        .filter(|j| j.str_of("type").unwrap() == "step")
        .map(|j| {
            (
                j.str_of("type").unwrap(),
                j.u64_of("step").unwrap(),
                (j.f64_of("loss").unwrap() as f32).to_bits(),
            )
        })
        .collect()
}

#[test]
fn two_jobs_interleave_and_match_solo_runs() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-interleave").unwrap();

    // solo baselines: each job on its own device, blocking run
    let solo_a = {
        let device = Device::cpu().unwrap();
        let mut t =
            Trainer::new(&device, job_cfg(&root, &scratch.join("solo-a"), Method::Revffn))
                .unwrap();
        t.run().unwrap();
        t.metrics.steps.iter().map(|r| (r.step, r.loss.to_bits())).collect::<Vec<_>>()
    };
    let solo_b = {
        let device = Device::cpu().unwrap();
        let mut t = Trainer::new(&device, job_cfg(&root, &scratch.join("solo-b"), Method::Sft))
            .unwrap();
        t.run().unwrap();
        t.metrics.steps.iter().map(|r| (r.step, r.loss.to_bits())).collect::<Vec<_>>()
    };

    // scheduled: both jobs share one device, quantum 1 forces maximal
    // interleaving (suspend/resume between every event)
    let device = Device::cpu().unwrap();
    let mut sched =
        Scheduler::new(device, serve_opts(&root, &scratch, 1e9, 1)).unwrap();
    let a = sched
        .submit(job_cfg(&root, &scratch.join("sched-a"), Method::Revffn), Some("a".into()))
        .unwrap();
    let b = sched
        .submit(job_cfg(&root, &scratch.join("sched-b"), Method::Sft), Some("b".into()))
        .unwrap();
    assert!(a.admitted && b.admitted);
    sched.run_until_idle().unwrap();
    assert_eq!(sched.job_state(&a.id), Some(JobState::Finished));
    assert_eq!(sched.job_state(&b.id), Some(JobState::Finished));

    let board = sched.board();
    let board = board.lock().unwrap();

    // the timeline must actually interleave: some b event lands between
    // two a events while both are active
    let tl = &board.timeline;
    let transitions = tl.windows(2).filter(|w| w[0] != w[1]).count();
    assert!(transitions >= 2, "expected interleaving, timeline: {tl:?}");

    // per-job losses bit-identical to the solo runs
    let sig_a = step_signature(&board.jobs[0].events.to_vec());
    let sig_b = step_signature(&board.jobs[1].events.to_vec());
    let solo_sig = |solo: &[(u64, u32)]| -> Vec<(String, u64, u32)> {
        solo.iter().map(|&(s, l)| ("step".to_string(), s, l)).collect()
    };
    assert_eq!(sig_a, solo_sig(&solo_a), "revffn losses must match the solo run bit-for-bit");
    assert_eq!(sig_b, solo_sig(&solo_b), "sft losses must match the solo run bit-for-bit");

    // reports recorded, budget fully released
    assert!(board.jobs[0].report.is_some());
    assert!(board.committed_gb == 0.0);
}

#[test]
fn scheduling_is_deterministic_across_runs() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-determinism").unwrap();

    let run_once = |tag: &str| -> (Vec<String>, Vec<Vec<(String, u64, u32)>>) {
        let device = Device::cpu().unwrap();
        let mut sched =
            Scheduler::new(device, serve_opts(&root, &scratch, 1e9, 2)).unwrap();
        sched
            .submit(
                job_cfg(&root, &scratch.join(format!("{tag}-a")), Method::Revffn),
                None,
            )
            .unwrap();
        sched
            .submit(job_cfg(&root, &scratch.join(format!("{tag}-b")), Method::Sft), None)
            .unwrap();
        sched.run_until_idle().unwrap();
        let board = sched.board();
        let board = board.lock().unwrap();
        let sigs = board.jobs.iter().map(|j| step_signature(&j.events.to_vec())).collect();
        (board.timeline.clone(), sigs)
    };

    let (tl1, sig1) = run_once("r1");
    let (tl2, sig2) = run_once("r2");
    assert_eq!(tl1, tl2, "same submissions must interleave identically");
    assert_eq!(sig1, sig2, "per-job step streams must be identical");
}

#[test]
fn admission_queues_past_budget_and_admits_fifo() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-admission").unwrap();

    // budget fits exactly one tiny job at a time
    let assume = revffn::memory::Assumptions::parse("f32").unwrap();
    let priced = admission::price_job(&root, Method::Sft, assume, None).unwrap();
    let budget = 1.5 * priced.peak_gb;

    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, serve_opts(&root, &scratch, budget, 4)).unwrap();
    let a = sched
        .submit(job_cfg(&root, &scratch.join("adm-a"), Method::Sft), None)
        .unwrap();
    let b = sched
        .submit(job_cfg(&root, &scratch.join("adm-b"), Method::Sft), None)
        .unwrap();
    assert!(a.admitted, "first job must be admitted");
    assert!(!b.admitted, "second job must queue behind the budget");
    assert_eq!(sched.job_state(&b.id), Some(JobState::Queued));

    sched.run_until_idle().unwrap();
    assert_eq!(sched.job_state(&a.id), Some(JobState::Finished));
    assert_eq!(sched.job_state(&b.id), Some(JobState::Finished), "queued job must run after");

    // with serialized admission, every a event precedes every b event
    let board = sched.board();
    let board = board.lock().unwrap();
    let last_a = board.timeline.iter().rposition(|id| id == &a.id).unwrap();
    let first_b = board.timeline.iter().position(|id| id == &b.id).unwrap();
    assert!(last_a < first_b, "budget-serialized jobs must not interleave");
}

#[test]
fn oversized_job_rejected_outright() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-oversize").unwrap();
    let device = Device::cpu().unwrap();
    // a budget far below one tiny job's f32 peak
    let mut sched =
        Scheduler::new(device, serve_opts(&root, &scratch, 1e-6, 4)).unwrap();
    let r = sched.submit(job_cfg(&root, &scratch.join("big"), Method::Sft), None);
    assert!(r.is_err(), "a job pricing over the whole budget can never run");
}

#[test]
fn tcp_control_plane_end_to_end() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-tcp").unwrap();
    let handle = revffn::serve::serve(serve_opts(&root, &scratch, 1e9, 2)).unwrap();
    let addr = handle.addr().to_string();

    let send = |stream: &mut TcpStream, req: &Request| {
        let mut line = req.to_line();
        line.push('\n');
        stream.write_all(line.as_bytes()).unwrap();
        stream.flush().unwrap();
    };
    let read = |reader: &mut BufReader<TcpStream>| -> Json {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        json::parse(line.trim()).unwrap_or_else(|e| panic!("{e}: {line:?}"))
    };

    let mut control = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(control.try_clone().unwrap());

    // submit one short job (config keys omitted fall back to serve
    // defaults: artifacts dir, out_dir under run_root)
    let cfg = json::parse(
        r#"{"method":"revffn","eval_every":0,"eval_batches":1,
            "schedule":{"stage1_steps":1,"stage2_steps":2},
            "data":{"pretrain_steps":0,"n_train":48,"n_eval":16}}"#,
    )
    .unwrap();
    send(
        &mut control,
        &Request::Submit {
            config: cfg,
            name: Some("tcp".into()),
            priority: Priority::Normal,
            tenant: None,
            deadline_ms: None,
        },
    );
    let resp = read(&mut reader);
    assert!(resp.bool_of("ok").unwrap(), "submit failed: {resp}");
    let job = resp.str_of("job").unwrap();
    assert!(resp.bool_of("admitted").unwrap());
    assert!(resp.f64_of("peak_gb").unwrap() > 0.0);

    // follow the event stream on a second connection until done
    let mut ev_stream = TcpStream::connect(&addr).unwrap();
    send(&mut ev_stream, &Request::Events { job: job.clone(), from: 0, limit: None, follow: true });
    let mut ev_reader = BufReader::new(ev_stream.try_clone().unwrap());
    let mut step_events = 0;
    let mut phases = Vec::new();
    loop {
        let j = read(&mut ev_reader);
        if j.get("done").and_then(Json::as_bool).unwrap_or(false) {
            assert_eq!(j.str_of("state").unwrap(), "finished");
            break;
        }
        assert_eq!(j.str_of("job").unwrap(), job);
        match j.str_of("type").unwrap().as_str() {
            "step" => step_events += 1,
            "phase_started" => phases.push(j.u64_of("stage").unwrap()),
            _ => {}
        }
    }
    assert_eq!(step_events, 3, "1 stage-1 + 2 stage-2 steps");
    assert_eq!(phases, vec![1, 2]);

    // status reflects the finished job
    send(&mut control, &Request::Status { job: Some(job.clone()) });
    let status = read(&mut reader);
    let rows = status.arr_of("jobs").unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].str_of("state").unwrap(), "finished");
    assert_eq!(rows[0].u64_of("steps_done").unwrap(), 3);

    // cancelling a finished job reports cancelled=false
    send(&mut control, &Request::Cancel { job: job.clone() });
    let c = read(&mut reader);
    assert!(c.bool_of("ok").unwrap());
    assert!(!c.bool_of("cancelled").unwrap());

    // unknown job errors cleanly
    send(&mut control, &Request::Cancel { job: "job-999".into() });
    assert!(!read(&mut reader).bool_of("ok").unwrap());

    send(&mut control, &Request::Shutdown);
    assert!(read(&mut reader).bool_of("ok").unwrap());
    handle.join().unwrap();
}

#[test]
fn cancel_running_job_frees_budget() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-cancel").unwrap();
    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, serve_opts(&root, &scratch, 1e9, 1)).unwrap();
    let mut cfg = job_cfg(&root, &scratch.join("c"), Method::Sft);
    cfg.schedule.stage2_steps = 50; // long enough to cancel mid-run
    let a = sched.submit(cfg, None).unwrap();
    // a few quanta, then cancel mid-flight
    for _ in 0..4 {
        assert!(sched.tick().unwrap());
    }
    assert!(sched.cancel(&a.id).unwrap());
    assert_eq!(sched.job_state(&a.id), Some(JobState::Cancelled));
    assert!(!sched.tick().unwrap(), "no work after cancelling the only job");
    let board = sched.board();
    let board = board.lock().unwrap();
    assert_eq!(board.committed_gb, 0.0, "cancelled job must release its reservation");
    assert!(board.jobs[0].snap.events > 0, "events before the cancel survive");
}

/// Per-job (stage, step) → loss-bits map of a board job's step events.
/// Keyed on both because the optimizer step counter restarts per phase.
fn step_map(events: &[String]) -> std::collections::HashMap<(u64, u64), u32> {
    events
        .iter()
        .map(|l| json::parse(l).unwrap())
        .filter(|j| j.str_of("type").unwrap() == "step")
        .map(|j| {
            (
                (j.u64_of("stage").unwrap(), j.u64_of("step").unwrap()),
                (j.f64_of("loss").unwrap() as f32).to_bits(),
            )
        })
        .collect()
}

#[test]
fn cancelled_job_resumes_bit_identically() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-resume").unwrap();

    // solo baseline for the whole schedule
    let solo = {
        let device = Device::cpu().unwrap();
        let mut t =
            Trainer::new(&device, job_cfg(&root, &scratch.join("solo"), Method::Revffn)).unwrap();
        t.run().unwrap();
        t.metrics
            .steps
            .iter()
            .map(|r| ((r.stage as u64, r.step), r.loss.to_bits()))
            .collect::<std::collections::HashMap<_, _>>()
    };

    // scheduled job with periodic snapshots, killed (cancelled) mid-run
    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, serve_opts(&root, &scratch, 1e9, 1)).unwrap();
    let mut cfg = job_cfg(&root, &scratch.join("job"), Method::Revffn);
    cfg.checkpoint_every = 1;
    cfg.keep_last = 0; // keep every snapshot
    let a = sched.submit(cfg, Some("crashy".into())).unwrap();
    assert!(a.admitted);
    // enough quanta (1 event each) to clear a couple of optimizer steps
    for _ in 0..6 {
        assert!(sched.tick().unwrap());
    }
    assert!(sched.cancel(&a.id).unwrap());

    // bring it back from its latest snapshot and drive to completion
    let resumed = sched.resume_job(&a.id).expect("cancelled job with snapshots must resume");
    assert_ne!(resumed.id, a.id, "the continuation is a new job");
    assert!(resumed.admitted);
    sched.run_until_idle().unwrap();
    assert_eq!(sched.job_state(&resumed.id), Some(JobState::Finished));
    assert_eq!(sched.job_state(&a.id), Some(JobState::Cancelled), "original stays terminal");

    let board = sched.board();
    let board = board.lock().unwrap();
    let original = board.job(&a.id).unwrap();
    let cont = board.job(&resumed.id).unwrap();

    // every step either job recorded matches the solo run bit-for-bit —
    // THE crash-safety guarantee: resume restores moments + data
    // cursor, so the continuation is the same training trajectory
    for (key, loss) in
        step_map(&original.events.to_vec()).iter().chain(step_map(&cont.events.to_vec()).iter())
    {
        assert_eq!(
            Some(loss),
            solo.get(key).as_deref(),
            "stage/step {key:?} diverged from the solo run"
        );
    }
    // the continuation reached the end of the schedule
    let solo_last = *solo.keys().max().unwrap();
    assert!(
        step_map(&cont.events.to_vec()).contains_key(&solo_last),
        "resumed job must run through the final stage/step {solo_last:?}"
    );
    // event numbering continued from the snapshot instead of resetting
    assert!(cont.events.base() > 0, "resumed log starts at the cursor's seq");

    // resuming a finished job is refused
    assert!(sched.resume_job(&resumed.id).is_err());
}

#[test]
fn restarted_scheduler_recovers_jobs_from_disk() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-recover").unwrap();
    let opts = {
        let mut o = serve_opts(&root, &scratch, 1e9, 1);
        o.checkpoint_every = 1; // serve-level default cadence
        o
    };

    // first server life: submit over the wire shape (checkpoint_every
    // OMITTED → the serve default cadence applies; an explicit 0 would
    // opt out), run a few quanta, then drop the scheduler with the job
    // mid-flight (the "crash")
    let out_dir = opts.run_root.join("job-0");
    let a = {
        let device = Device::cpu().unwrap();
        let mut sched = Scheduler::new(device, opts.clone()).unwrap();
        let cfg_json = json::parse(&format!(
            r#"{{"method":"revffn","eval_every":0,"eval_batches":1,"out_dir":{:?},
                "schedule":{{"stage1_steps":2,"stage2_steps":3,"warmup_steps":1}},
                "data":{{"pretrain_steps":0,"n_train":48,"n_eval":16}}}}"#,
            out_dir.to_str().unwrap()
        ))
        .unwrap();
        let a = sched.submit_json(&cfg_json, Some("survivor".into()), SubmitMeta::default()).unwrap();
        assert!(a.admitted);
        for _ in 0..6 {
            assert!(sched.tick().unwrap());
        }
        a
    };
    assert!(
        revffn::checkpoint::latest_checkpoint(&out_dir).is_some(),
        "serve default cadence must have produced snapshots"
    );
    assert!(
        opts.run_root.join("job-0").join("job.json").exists(),
        "running job must leave its recovery marker"
    );

    // second server life: recover() finds the marker + snapshots
    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, opts.clone()).unwrap();
    assert_eq!(sched.recover(), 1, "one interrupted job must come back");
    sched.run_until_idle().unwrap();
    let board = sched.board();
    let board = board.lock().unwrap();
    assert_eq!(board.jobs.len(), 1);
    assert_eq!(board.jobs[0].snap.state, JobState::Finished);
    assert_eq!(board.jobs[0].snap.name, "survivor", "recovered under its original name");
    let _ = a;
    assert!(
        !opts.run_root.join("job-0").join("job.json").exists(),
        "finished job must clear its recovery marker"
    );
}

#[test]
fn interactive_job_overtakes_queued_batch_backlog() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-priority").unwrap();

    // budget fits exactly one tiny job at a time — a real backlog forms
    let assume = revffn::memory::Assumptions::parse("f32").unwrap();
    let priced = admission::price_job(&root, Method::Sft, assume, None).unwrap();
    let budget = 1.5 * priced.peak_gb;

    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, serve_opts(&root, &scratch, budget, 4)).unwrap();
    let batch = SubmitMeta { priority: Priority::Batch, ..SubmitMeta::default() };
    let b1 = sched
        .submit_with(job_cfg(&root, &scratch.join("b1"), Method::Sft), None, batch.clone())
        .unwrap();
    let b2 = sched
        .submit_with(job_cfg(&root, &scratch.join("b2"), Method::Sft), None, batch.clone())
        .unwrap();
    let b3 = sched
        .submit_with(job_cfg(&root, &scratch.join("b3"), Method::Sft), None, batch)
        .unwrap();
    let hi = sched
        .submit_with(
            job_cfg(&root, &scratch.join("hi"), Method::Sft),
            None,
            SubmitMeta { priority: Priority::Interactive, ..SubmitMeta::default() },
        )
        .unwrap();
    assert!(b1.admitted, "first batch job owns the budget");
    assert!(!b2.admitted && !b3.admitted && !hi.admitted, "the rest must queue");

    sched.run_until_idle().unwrap();
    for id in [&b1.id, &b2.id, &b3.id, &hi.id] {
        assert_eq!(sched.job_state(id), Some(JobState::Finished));
    }

    // the interactive job must be the FIRST admission out of the
    // backlog, overtaking both earlier-submitted batch jobs — and the
    // batch pair must then drain in submit order
    let board = sched.board();
    let board = board.lock().unwrap();
    let first_seen = |id: &str| board.timeline.iter().position(|t| t == id).unwrap();
    assert!(
        first_seen(&hi.id) < first_seen(&b2.id) && first_seen(&hi.id) < first_seen(&b3.id),
        "interactive job must run before the queued batch jobs: {:?}",
        board.timeline
    );
    assert!(first_seen(&b2.id) < first_seen(&b3.id), "equal jobs keep submit order");
}

#[test]
fn tenant_at_quota_waits_while_other_tenant_admits() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-tenant-quota").unwrap();
    let opts = {
        let mut o = serve_opts(&root, &scratch, 1e9, 4);
        o.tenant_max_jobs = 1; // budget is effectively unlimited; the quota is the gate
        o
    };
    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, opts).unwrap();
    let meta = |tenant: &str| SubmitMeta { tenant: Some(tenant.into()), ..SubmitMeta::default() };

    let a1 = sched
        .submit_with(job_cfg(&root, &scratch.join("a1"), Method::Sft), None, meta("team-a"))
        .unwrap();
    let a2 = sched
        .submit_with(job_cfg(&root, &scratch.join("a2"), Method::Sft), None, meta("team-a"))
        .unwrap();
    let b1 = sched
        .submit_with(job_cfg(&root, &scratch.join("bb"), Method::Sft), None, meta("team-b"))
        .unwrap();

    assert!(a1.admitted, "within quota");
    assert!(!a2.admitted, "tenant at max_jobs must wait despite free budget");
    assert_eq!(sched.job_state(&a2.id), Some(JobState::Queued));
    assert!(b1.admitted, "a quota-blocked tenant must not block others");

    sched.run_until_idle().unwrap();
    for id in [&a1.id, &a2.id, &b1.id] {
        assert_eq!(sched.job_state(id), Some(JobState::Finished), "quota releases free the queue");
    }
    // a2 only started once a1 released team-a's slot
    let board = sched.board();
    let board = board.lock().unwrap();
    let last_a1 = board.timeline.iter().rposition(|t| t == &a1.id).unwrap();
    let first_a2 = board.timeline.iter().position(|t| t == &a2.id).unwrap();
    assert!(last_a1 < first_a2, "tenant slot must serialize a1 before a2");
}

#[test]
fn tcp_paginated_events_reconstruct_the_full_replay() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-pages").unwrap();
    let handle = revffn::serve::serve(serve_opts(&root, &scratch, 1e9, 2)).unwrap();
    let addr = handle.addr().to_string();

    let send = |stream: &mut TcpStream, req: &Request| {
        let mut line = req.to_line();
        line.push('\n');
        stream.write_all(line.as_bytes()).unwrap();
        stream.flush().unwrap();
    };
    let read = |reader: &mut BufReader<TcpStream>| -> Json {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        json::parse(line.trim()).unwrap_or_else(|e| panic!("{e}: {line:?}"))
    };

    let mut control = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(control.try_clone().unwrap());
    let cfg = json::parse(
        r#"{"method":"revffn","eval_every":0,"eval_batches":1,
            "schedule":{"stage1_steps":1,"stage2_steps":2},
            "data":{"pretrain_steps":0,"n_train":48,"n_eval":16}}"#,
    )
    .unwrap();
    send(
        &mut control,
        &Request::Submit {
            config: cfg,
            name: None,
            priority: Priority::Interactive,
            tenant: Some("pager".into()),
            deadline_ms: Some(120_000),
        },
    );
    let resp = read(&mut reader);
    assert!(resp.bool_of("ok").unwrap(), "submit failed: {resp}");
    assert_eq!(resp.str_of("priority").unwrap(), "interactive");
    assert_eq!(resp.str_of("tenant").unwrap(), "pager");
    let job = resp.str_of("job").unwrap();

    // the reference: one follow stream, every event line until done
    let mut full = Vec::new();
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        send(&mut s, &Request::Events { job: job.clone(), from: 0, limit: None, follow: true });
        let mut r = BufReader::new(s.try_clone().unwrap());
        loop {
            let j = read(&mut r);
            if j.get("done").and_then(Json::as_bool).unwrap_or(false) {
                assert_eq!(j.str_of("state").unwrap(), "finished");
                break;
            }
            full.push(j.to_string());
        }
    }
    assert!(full.len() > 4, "short job still emits a multi-page stream");

    // now reconstruct it with limit-2 pages chained through next_cursor
    let mut paged = Vec::new();
    let mut cursor = 0u64;
    loop {
        let mut s = TcpStream::connect(&addr).unwrap();
        send(
            &mut s,
            &Request::Events { job: job.clone(), from: cursor, limit: Some(2), follow: false },
        );
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut count = 0u64;
        loop {
            let j = read(&mut r);
            if j.get("page").and_then(Json::as_bool).unwrap_or(false) {
                assert_eq!(j.u64_of("count").unwrap(), count, "footer count = delivered lines");
                let next = j.u64_of("next_cursor").unwrap();
                assert_eq!(next, cursor + count, "next_cursor advances by the page length");
                cursor = next;
                if j.bool_of("done").unwrap() {
                    assert_eq!(j.str_of("state").unwrap(), "finished");
                } else {
                    assert_eq!(count, 2, "only the final page may come up short");
                }
                break;
            }
            count += 1;
            assert!(count <= 2, "page overflowed its limit");
            paged.push(j.to_string());
        }
        if cursor >= full.len() as u64 {
            break;
        }
    }
    assert_eq!(paged, full, "chained pages must reconstruct the exact replay");

    // an idle retry past the end is exact: zero lines, echoed cursor
    let mut s = TcpStream::connect(&addr).unwrap();
    send(&mut s, &Request::Events { job: job.clone(), from: cursor, limit: Some(2), follow: false });
    let mut r = BufReader::new(s.try_clone().unwrap());
    let j = read(&mut r);
    assert!(j.get("page").and_then(Json::as_bool).unwrap_or(false));
    assert_eq!(j.u64_of("count").unwrap(), 0);
    assert_eq!(j.u64_of("next_cursor").unwrap(), cursor);
    assert!(j.bool_of("done").unwrap());

    send(&mut control, &Request::Shutdown);
    assert!(read(&mut reader).bool_of("ok").unwrap());
    handle.join().unwrap();
}

#[test]
fn event_log_cap_keeps_streams_bounded_and_contiguous() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-logcap").unwrap();
    let opts = {
        let mut o = serve_opts(&root, &scratch, 1e9, 4);
        o.event_log_cap = 3;
        o
    };
    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, opts).unwrap();
    let a = sched.submit(job_cfg(&root, &scratch.join("cap"), Method::Sft), None).unwrap();
    sched.run_until_idle().unwrap();
    let board = sched.board();
    let board = board.lock().unwrap();
    let view = board.job(&a.id).unwrap();
    assert!(view.snap.events > 3, "job emits more events than the cap");
    assert_eq!(view.events.len(), 3, "ring retains exactly the cap");
    assert_eq!(
        view.events.base() + view.events.len() as u64,
        view.snap.events,
        "base + retained = total: the stream is contiguous"
    );
    // a subscriber from 0 is clamped to the base, not served a gap
    let (lines, start) = view.events.lines_from(0);
    assert_eq!(start, view.events.base());
    assert_eq!(lines.len(), 3);
}
