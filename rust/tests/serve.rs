//! Serve-subsystem integration tests over the real AOT artifacts:
//! concurrent jobs must interleave deterministically on one shared
//! device and finish with losses bit-identical to running each job
//! solo; admission must queue past-budget jobs FIFO and admit them as
//! budget frees; the TCP control plane must speak the NDJSON protocol
//! end to end.
//!
//! Like the other integration tests, everything skips silently when
//! `artifacts/tiny` is absent (run `make artifacts` first).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};

use revffn::config::{PriceGeometry, RunConfig, ServeConfig};
use revffn::coordinator::Trainer;
use revffn::engine::Method;
use revffn::runtime::Device;
use revffn::serve::protocol::{JobState, Request};
use revffn::serve::{admission, Scheduler};
use revffn::util::json::{self, Json};
use revffn::util::ScratchDir;

fn artifacts_root() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    p.join("index.json").exists().then_some(p)
}

/// A short run of `method` (pre-pass off, eval only at stage ends).
fn job_cfg(root: &Path, out: &Path, method: Method) -> RunConfig {
    let mut cfg = RunConfig::default_tiny(root);
    cfg.method = method;
    cfg.schedule.stage1_steps = if method.is_two_stage() { 2 } else { 0 };
    cfg.schedule.stage2_steps = 3;
    cfg.schedule.warmup_steps = 1;
    cfg.data.pretrain_steps = 0;
    cfg.data.n_train = 48;
    cfg.data.n_eval = 16;
    cfg.eval_every = 0;
    cfg.eval_batches = 1;
    cfg.out_dir = out.into();
    cfg
}

fn serve_opts(root: &Path, scratch: &Path, budget_gb: f64, quantum: u64) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        artifacts: root.to_path_buf(),
        budget_gb,
        quantum,
        assumptions: "f32".into(),
        price_geometry: PriceGeometry::Manifest,
        run_root: scratch.join("serve"),
    }
}

/// (type, step, loss-bits) triples of a job's step events — the
/// deterministic projection (wall-clock fields excluded).
fn step_signature(events: &[String]) -> Vec<(String, u64, u32)> {
    events
        .iter()
        .map(|l| json::parse(l).unwrap())
        .filter(|j| j.str_of("type").unwrap() == "step")
        .map(|j| {
            (
                j.str_of("type").unwrap(),
                j.u64_of("step").unwrap(),
                (j.f64_of("loss").unwrap() as f32).to_bits(),
            )
        })
        .collect()
}

#[test]
fn two_jobs_interleave_and_match_solo_runs() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-interleave").unwrap();

    // solo baselines: each job on its own device, blocking run
    let solo_a = {
        let device = Device::cpu().unwrap();
        let mut t =
            Trainer::new(&device, job_cfg(&root, &scratch.join("solo-a"), Method::Revffn))
                .unwrap();
        t.run().unwrap();
        t.metrics.steps.iter().map(|r| (r.step, r.loss.to_bits())).collect::<Vec<_>>()
    };
    let solo_b = {
        let device = Device::cpu().unwrap();
        let mut t = Trainer::new(&device, job_cfg(&root, &scratch.join("solo-b"), Method::Sft))
            .unwrap();
        t.run().unwrap();
        t.metrics.steps.iter().map(|r| (r.step, r.loss.to_bits())).collect::<Vec<_>>()
    };

    // scheduled: both jobs share one device, quantum 1 forces maximal
    // interleaving (suspend/resume between every event)
    let device = Device::cpu().unwrap();
    let mut sched =
        Scheduler::new(device, serve_opts(&root, &scratch, 1e9, 1)).unwrap();
    let a = sched
        .submit(job_cfg(&root, &scratch.join("sched-a"), Method::Revffn), Some("a".into()))
        .unwrap();
    let b = sched
        .submit(job_cfg(&root, &scratch.join("sched-b"), Method::Sft), Some("b".into()))
        .unwrap();
    assert!(a.admitted && b.admitted);
    sched.run_until_idle().unwrap();
    assert_eq!(sched.job_state(&a.id), Some(JobState::Finished));
    assert_eq!(sched.job_state(&b.id), Some(JobState::Finished));

    let board = sched.board();
    let board = board.lock().unwrap();

    // the timeline must actually interleave: some b event lands between
    // two a events while both are active
    let tl = &board.timeline;
    let transitions = tl.windows(2).filter(|w| w[0] != w[1]).count();
    assert!(transitions >= 2, "expected interleaving, timeline: {tl:?}");

    // per-job losses bit-identical to the solo runs
    let sig_a = step_signature(&board.jobs[0].events);
    let sig_b = step_signature(&board.jobs[1].events);
    let solo_sig = |solo: &[(u64, u32)]| -> Vec<(String, u64, u32)> {
        solo.iter().map(|&(s, l)| ("step".to_string(), s, l)).collect()
    };
    assert_eq!(sig_a, solo_sig(&solo_a), "revffn losses must match the solo run bit-for-bit");
    assert_eq!(sig_b, solo_sig(&solo_b), "sft losses must match the solo run bit-for-bit");

    // reports recorded, budget fully released
    assert!(board.jobs[0].report.is_some());
    assert!(board.committed_gb == 0.0);
}

#[test]
fn scheduling_is_deterministic_across_runs() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-determinism").unwrap();

    let run_once = |tag: &str| -> (Vec<String>, Vec<Vec<(String, u64, u32)>>) {
        let device = Device::cpu().unwrap();
        let mut sched =
            Scheduler::new(device, serve_opts(&root, &scratch, 1e9, 2)).unwrap();
        sched
            .submit(
                job_cfg(&root, &scratch.join(format!("{tag}-a")), Method::Revffn),
                None,
            )
            .unwrap();
        sched
            .submit(job_cfg(&root, &scratch.join(format!("{tag}-b")), Method::Sft), None)
            .unwrap();
        sched.run_until_idle().unwrap();
        let board = sched.board();
        let board = board.lock().unwrap();
        let sigs = board.jobs.iter().map(|j| step_signature(&j.events)).collect();
        (board.timeline.clone(), sigs)
    };

    let (tl1, sig1) = run_once("r1");
    let (tl2, sig2) = run_once("r2");
    assert_eq!(tl1, tl2, "same submissions must interleave identically");
    assert_eq!(sig1, sig2, "per-job step streams must be identical");
}

#[test]
fn admission_queues_past_budget_and_admits_fifo() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-admission").unwrap();

    // budget fits exactly one tiny job at a time
    let assume = revffn::memory::Assumptions::parse("f32").unwrap();
    let priced = admission::price_job(&root, Method::Sft, assume, None).unwrap();
    let budget = 1.5 * priced.peak_gb;

    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, serve_opts(&root, &scratch, budget, 4)).unwrap();
    let a = sched
        .submit(job_cfg(&root, &scratch.join("adm-a"), Method::Sft), None)
        .unwrap();
    let b = sched
        .submit(job_cfg(&root, &scratch.join("adm-b"), Method::Sft), None)
        .unwrap();
    assert!(a.admitted, "first job must be admitted");
    assert!(!b.admitted, "second job must queue behind the budget");
    assert_eq!(sched.job_state(&b.id), Some(JobState::Queued));

    sched.run_until_idle().unwrap();
    assert_eq!(sched.job_state(&a.id), Some(JobState::Finished));
    assert_eq!(sched.job_state(&b.id), Some(JobState::Finished), "queued job must run after");

    // with serialized admission, every a event precedes every b event
    let board = sched.board();
    let board = board.lock().unwrap();
    let last_a = board.timeline.iter().rposition(|id| id == &a.id).unwrap();
    let first_b = board.timeline.iter().position(|id| id == &b.id).unwrap();
    assert!(last_a < first_b, "budget-serialized jobs must not interleave");
}

#[test]
fn oversized_job_rejected_outright() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-oversize").unwrap();
    let device = Device::cpu().unwrap();
    // a budget far below one tiny job's f32 peak
    let mut sched =
        Scheduler::new(device, serve_opts(&root, &scratch, 1e-6, 4)).unwrap();
    let r = sched.submit(job_cfg(&root, &scratch.join("big"), Method::Sft), None);
    assert!(r.is_err(), "a job pricing over the whole budget can never run");
}

#[test]
fn tcp_control_plane_end_to_end() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-tcp").unwrap();
    let handle = revffn::serve::serve(serve_opts(&root, &scratch, 1e9, 2)).unwrap();
    let addr = handle.addr().to_string();

    let send = |stream: &mut TcpStream, req: &Request| {
        let mut line = req.to_line();
        line.push('\n');
        stream.write_all(line.as_bytes()).unwrap();
        stream.flush().unwrap();
    };
    let read = |reader: &mut BufReader<TcpStream>| -> Json {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        json::parse(line.trim()).unwrap_or_else(|e| panic!("{e}: {line:?}"))
    };

    let mut control = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(control.try_clone().unwrap());

    // submit one short job (config keys omitted fall back to serve
    // defaults: artifacts dir, out_dir under run_root)
    let cfg = json::parse(
        r#"{"method":"revffn","eval_every":0,"eval_batches":1,
            "schedule":{"stage1_steps":1,"stage2_steps":2},
            "data":{"pretrain_steps":0,"n_train":48,"n_eval":16}}"#,
    )
    .unwrap();
    send(&mut control, &Request::Submit { config: cfg, name: Some("tcp".into()) });
    let resp = read(&mut reader);
    assert!(resp.bool_of("ok").unwrap(), "submit failed: {resp}");
    let job = resp.str_of("job").unwrap();
    assert!(resp.bool_of("admitted").unwrap());
    assert!(resp.f64_of("peak_gb").unwrap() > 0.0);

    // follow the event stream on a second connection until done
    let mut ev_stream = TcpStream::connect(&addr).unwrap();
    send(&mut ev_stream, &Request::Events { job: job.clone(), from: 0, follow: true });
    let mut ev_reader = BufReader::new(ev_stream.try_clone().unwrap());
    let mut step_events = 0;
    let mut phases = Vec::new();
    loop {
        let j = read(&mut ev_reader);
        if j.get("done").and_then(Json::as_bool).unwrap_or(false) {
            assert_eq!(j.str_of("state").unwrap(), "finished");
            break;
        }
        assert_eq!(j.str_of("job").unwrap(), job);
        match j.str_of("type").unwrap().as_str() {
            "step" => step_events += 1,
            "phase_started" => phases.push(j.u64_of("stage").unwrap()),
            _ => {}
        }
    }
    assert_eq!(step_events, 3, "1 stage-1 + 2 stage-2 steps");
    assert_eq!(phases, vec![1, 2]);

    // status reflects the finished job
    send(&mut control, &Request::Status { job: Some(job.clone()) });
    let status = read(&mut reader);
    let rows = status.arr_of("jobs").unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].str_of("state").unwrap(), "finished");
    assert_eq!(rows[0].u64_of("steps_done").unwrap(), 3);

    // cancelling a finished job reports cancelled=false
    send(&mut control, &Request::Cancel { job: job.clone() });
    let c = read(&mut reader);
    assert!(c.bool_of("ok").unwrap());
    assert!(!c.bool_of("cancelled").unwrap());

    // unknown job errors cleanly
    send(&mut control, &Request::Cancel { job: "job-999".into() });
    assert!(!read(&mut reader).bool_of("ok").unwrap());

    send(&mut control, &Request::Shutdown);
    assert!(read(&mut reader).bool_of("ok").unwrap());
    handle.join().unwrap();
}

#[test]
fn cancel_running_job_frees_budget() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("serve-cancel").unwrap();
    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, serve_opts(&root, &scratch, 1e9, 1)).unwrap();
    let mut cfg = job_cfg(&root, &scratch.join("c"), Method::Sft);
    cfg.schedule.stage2_steps = 50; // long enough to cancel mid-run
    let a = sched.submit(cfg, None).unwrap();
    // a few quanta, then cancel mid-flight
    for _ in 0..4 {
        assert!(sched.tick().unwrap());
    }
    assert!(sched.cancel(&a.id).unwrap());
    assert_eq!(sched.job_state(&a.id), Some(JobState::Cancelled));
    assert!(!sched.tick().unwrap(), "no work after cancelling the only job");
    let board = sched.board();
    let board = board.lock().unwrap();
    assert_eq!(board.committed_gb, 0.0, "cancelled job must release its reservation");
    assert!(board.jobs[0].snap.events > 0, "events before the cancel survive");
}
