//! `revffn check` end-to-end over the committed seeded-defect fixtures
//! (`tests/fixtures/check/`): every planted defect must be caught with
//! its stable rule ID, and the clean fixture must produce zero findings
//! — the same invariants the CI static job enforces through the CLI.

use std::path::PathBuf;

use revffn::analysis::configcheck::ConfigCheckOpts;
use revffn::analysis::lint::lint_text;
use revffn::analysis::{check_artifacts, check_checkpoint, check_config, Report};

fn fixture(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/check").join(rel)
}

#[test]
fn clean_fixture_is_clean() {
    let report = Report::new(check_artifacts(&fixture("clean")));
    assert!(
        report.ok() && report.findings.is_empty(),
        "clean fixture must produce zero findings:\n{}",
        report.render_text()
    );
}

#[test]
fn missing_pair_half_is_ar003() {
    // accum_step was removed from the inventory while scale stayed —
    // grad-accum submissions would fail at first use
    let report = Report::new(check_artifacts(&fixture("missing_accum")));
    assert!(report.has("AR003"), "expected AR003:\n{}", report.render_text());
    assert!(!report.ok());
    let f = report.findings.iter().find(|f| f.rule == "AR003").unwrap();
    assert!(f.subject.contains("accum_step"), "wrong subject: {}", f.subject);
}

#[test]
fn fabricated_manifest_shape_is_ar007() {
    // manifest claims embed is [5,2]; the compiled program still takes
    // f32[4,2] — feeding it manifest-shaped buffers would abort
    let report = Report::new(check_artifacts(&fixture("bad_shape")));
    assert!(report.has("AR007"), "expected AR007:\n{}", report.render_text());
    assert!(!report.has("AR002"), "fixture must be internally consistent");
}

#[test]
fn dtype_flip_is_ar007() {
    // manifest says embed is f16 (nbytes consistent at 2 bytes/elem),
    // but the program takes f32[4,2]
    let report = Report::new(check_artifacts(&fixture("dtype_flip")));
    assert!(report.has("AR007"), "expected AR007:\n{}", report.render_text());
    assert!(!report.has("AR002"));
    let f = report.findings.iter().find(|f| f.rule == "AR007").unwrap();
    assert!(f.message.contains("f16"), "message should name the dtype: {}", f.message);
}

#[test]
fn truncated_checkpoint_is_ck001() {
    let report =
        Report::new(check_checkpoint(&fixture("truncated.rvt"), &fixture("clean/sft")));
    assert!(report.has("CK001"), "expected CK001:\n{}", report.render_text());
}

#[test]
fn over_budget_serve_config_is_cf002() {
    let opts = ConfigCheckOpts {
        artifacts: Some(fixture("clean")),
        ..Default::default()
    };
    let report = Report::new(check_config(&fixture("over_budget_serve.json"), &opts));
    assert!(report.has("CF002"), "expected CF002:\n{}", report.render_text());
    assert!(!report.ok());
}

#[test]
fn ok_serve_config_passes() {
    let opts = ConfigCheckOpts {
        artifacts: Some(fixture("clean")),
        ..Default::default()
    };
    let report = Report::new(check_config(&fixture("serve_ok.json"), &opts));
    assert!(report.ok(), "serve_ok must exit clean:\n{}", report.render_text());
}

#[test]
fn seeded_raw_instant_fixture_is_ln005() {
    // serve-style worker timing a quantum with a raw Instant::now()
    // instead of obs::span / obs::now — exactly one live defect; the
    // comment, string, and test-block occurrences must stay exempt
    let src = std::fs::read_to_string(fixture("instant_timing.rs.txt")).unwrap();
    let findings = lint_text("serve/worker.rs", &src);
    assert_eq!(findings.len(), 1, "expected exactly the seeded defect: {findings:?}");
    assert_eq!(findings[0].rule, "LN005");
    assert_eq!(findings[0].subject, "serve/worker.rs:12");
    // the same text inside obs/ is the sanctioned home of the clock
    assert!(
        lint_text("obs/trace.rs", &src).is_empty(),
        "obs/ is exempt from LN005"
    );
    // and outside the timed trees (serve/, engine/) the rule is off
    assert!(lint_text("util/retry.rs", &src).is_empty());
}

#[test]
fn all_rule_ids_are_stable_strings() {
    // defense against typo'd rule IDs drifting: the catalog in
    // docs/ANALYSIS.md is the source of truth; anything emitted by the
    // fixture sweep must be in it
    let catalog = [
        "AR001", "AR002", "AR003", "AR004", "AR005", "AR006", "AR007", "AR008", "AR009",
        "AR010", "CK001", "CK002", "CK003", "CK004", "CF001", "CF002", "CF003", "CF004",
        "LN000", "LN001", "LN002", "LN003", "LN004", "LN005",
    ];
    let mut findings = Vec::new();
    for dir in ["clean", "missing_accum", "bad_shape", "dtype_flip"] {
        findings.extend(check_artifacts(&fixture(dir)));
    }
    findings.extend(check_checkpoint(&fixture("truncated.rvt"), &fixture("clean/sft")));
    for f in &findings {
        assert!(catalog.contains(&f.rule), "rule {} not in the documented catalog", f.rule);
    }
}
