//! `revffn check` end-to-end over the committed seeded-defect fixtures
//! (`tests/fixtures/check/`): every planted defect must be caught with
//! its stable rule ID, and the clean fixture must produce zero findings
//! — the same invariants the CI static job enforces through the CLI.

use std::path::PathBuf;

use revffn::analysis::configcheck::ConfigCheckOpts;
use revffn::analysis::lint::lint_text;
use revffn::analysis::liveness::{check_hlo_mem, HloMemOpts};
use revffn::analysis::{check_artifacts, check_checkpoint, check_config, Report};

fn fixture(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/check").join(rel)
}

#[test]
fn clean_fixture_is_clean() {
    let report = Report::new(check_artifacts(&fixture("clean")));
    assert!(
        report.ok() && report.findings.is_empty(),
        "clean fixture must produce zero findings:\n{}",
        report.render_text()
    );
}

#[test]
fn missing_pair_half_is_ar003() {
    // accum_step was removed from the inventory while scale stayed —
    // grad-accum submissions would fail at first use
    let report = Report::new(check_artifacts(&fixture("missing_accum")));
    assert!(report.has("AR003"), "expected AR003:\n{}", report.render_text());
    assert!(!report.ok());
    let f = report.findings.iter().find(|f| f.rule == "AR003").unwrap();
    assert!(f.subject.contains("accum_step"), "wrong subject: {}", f.subject);
}

#[test]
fn fabricated_manifest_shape_is_ar007() {
    // manifest claims embed is [5,2]; the compiled program still takes
    // f32[4,2] — feeding it manifest-shaped buffers would abort
    let report = Report::new(check_artifacts(&fixture("bad_shape")));
    assert!(report.has("AR007"), "expected AR007:\n{}", report.render_text());
    assert!(!report.has("AR002"), "fixture must be internally consistent");
}

#[test]
fn dtype_flip_is_ar007() {
    // manifest says embed is f16 (nbytes consistent at 2 bytes/elem),
    // but the program takes f32[4,2]
    let report = Report::new(check_artifacts(&fixture("dtype_flip")));
    assert!(report.has("AR007"), "expected AR007:\n{}", report.render_text());
    assert!(!report.has("AR002"));
    let f = report.findings.iter().find(|f| f.rule == "AR007").unwrap();
    assert!(f.message.contains("f16"), "message should name the dtype: {}", f.message);
}

#[test]
fn truncated_checkpoint_is_ck001() {
    let report =
        Report::new(check_checkpoint(&fixture("truncated.rvt"), &fixture("clean/sft")));
    assert!(report.has("CK001"), "expected CK001:\n{}", report.render_text());
}

#[test]
fn over_budget_serve_config_is_cf002() {
    let opts = ConfigCheckOpts {
        artifacts: Some(fixture("clean")),
        ..Default::default()
    };
    let report = Report::new(check_config(&fixture("over_budget_serve.json"), &opts));
    assert!(report.has("CF002"), "expected CF002:\n{}", report.render_text());
    assert!(!report.ok());
}

#[test]
fn ok_serve_config_passes() {
    let opts = ConfigCheckOpts {
        artifacts: Some(fixture("clean")),
        ..Default::default()
    };
    let report = Report::new(check_config(&fixture("serve_ok.json"), &opts));
    assert!(report.ok(), "serve_ok must exit clean:\n{}", report.render_text());
}

#[test]
fn seeded_raw_instant_fixture_is_ln005() {
    // serve-style worker timing a quantum with a raw Instant::now()
    // instead of obs::span / obs::now — exactly one live defect; the
    // comment, string, and test-block occurrences must stay exempt
    let src = std::fs::read_to_string(fixture("instant_timing.rs.txt")).unwrap();
    let findings = lint_text("serve/worker.rs", &src);
    assert_eq!(findings.len(), 1, "expected exactly the seeded defect: {findings:?}");
    assert_eq!(findings[0].rule, "LN005");
    assert_eq!(findings[0].subject, "serve/worker.rs:12");
    // the same text inside obs/ is the sanctioned home of the clock
    assert!(
        lint_text("obs/trace.rs", &src).is_empty(),
        "obs/ is exempt from LN005"
    );
    // and outside the timed trees (serve/, engine/) the rule is off
    assert!(lint_text("util/retry.rs", &src).is_empty());
}

#[test]
fn seeded_wire_cast_fixture_is_ln006() {
    // wire-layer reader narrowing a frame length with a silent `as`
    // cast — exactly one live defect; the comment, string, float-cast,
    // and test-block occurrences must stay exempt
    let src = std::fs::read_to_string(fixture("wire_cast.rs.txt")).unwrap();
    let findings = lint_text("serve/protocol.rs", &src);
    assert_eq!(findings.len(), 1, "expected exactly the seeded defect: {findings:?}");
    assert_eq!(findings[0].rule, "LN006");
    assert_eq!(findings[0].subject, "serve/protocol.rs:13");
    // the same text elsewhere in serve/ (or the repo) may cast freely
    assert!(lint_text("serve/scheduler.rs", &src).is_empty());
    assert!(lint_text("util/json.rs", &src).is_empty());
}

#[test]
fn clean_fixture_hlo_mem_is_clean_with_full_drift_table() {
    let (findings, drift) = check_hlo_mem(&fixture("clean"), &HloMemOpts::default());
    let report = Report::new(findings);
    assert!(
        report.ok() && report.findings.is_empty(),
        "clean fixture must produce zero hlo-mem findings:\n{}",
        report.render_text()
    );
    // a static peak for every program of the variant's inventory
    let programs: Vec<&str> = drift.iter().map(|r| r.program.as_str()).collect();
    for p in ["train_step", "eval_step", "forward", "grad_step", "apply_step", "accum_step", "scale"]
    {
        assert!(programs.contains(&p), "missing drift row for {p}: {programs:?}");
    }
    for r in &drift {
        assert!(r.static_bytes > 0, "{}/{}: zero static peak", r.variant, r.program);
        assert!(!r.peak_at.is_empty());
    }
    // the documented worked example: the fused step peaks at the
    // log-softmax workspace, just under the analytic prediction
    let train = drift.iter().find(|r| r.program == "train_step").unwrap();
    assert_eq!(train.static_bytes, 9428);
    assert_eq!(train.peak_at, "%lse.14");
    assert!(train.ratio < 1.0 && train.ratio > 0.9, "ratio {}", train.ratio);
}

#[test]
fn inflated_intermediate_is_mm001() {
    // train_step carries a fabricated 16.7 MB intermediate the analytic
    // model knows nothing about — admission would under-price the job
    let (findings, _) = check_hlo_mem(&fixture("mm_inflated"), &HloMemOpts::default());
    let report = Report::new(findings);
    assert!(report.has("MM001"), "expected MM001:\n{}", report.render_text());
    assert!(!report.ok());
    for f in &report.findings {
        assert_eq!(f.rule, "MM001", "only MM001 may fire: {}", report.render_text());
    }
    let f = &report.findings[0];
    assert!(f.subject.ends_with("sft/train_step"), "subject: {}", f.subject);
    assert!(f.message.contains("%huge.15"), "peak attribution missing: {}", f.message);
    // JSON carries the same rule
    let j = report.to_json();
    assert_eq!(j.arr_of("findings").unwrap()[0].str_of("rule").unwrap(), "MM001");
}

#[test]
fn dropped_alias_is_mm003() {
    // train_step's calling convention donates the state prefix, but the
    // module header lost its input_output_alias map
    let (findings, drift) = check_hlo_mem(&fixture("mm_dropped_alias"), &HloMemOpts::default());
    let report = Report::new(findings);
    assert!(report.has("MM003"), "expected MM003:\n{}", report.render_text());
    for f in &report.findings {
        assert_eq!(f.rule, "MM003", "only MM003 may fire: {}", report.render_text());
    }
    assert!(report.findings[0].message.contains("input_output_alias"));
    // the drift row still exists — the peak is computable without the map
    assert!(drift.iter().any(|r| r.program == "train_step"));
}

#[test]
fn double_donation_is_mm002() {
    // parameter 0 claimed by outputs 0 and 2 — its buffer would be
    // counted twice by the donation accounting
    let (findings, _) = check_hlo_mem(&fixture("mm_double_donation"), &HloMemOpts::default());
    let report = Report::new(findings);
    assert!(report.has("MM002"), "expected MM002:\n{}", report.render_text());
    for f in &report.findings {
        assert_eq!(f.rule, "MM002", "only MM002 may fire: {}", report.render_text());
    }
    assert!(report.findings[0].message.contains("parameter 0"));
    assert!(report.findings[0].message.contains("2 outputs"));
}

#[test]
fn all_rule_ids_are_stable_strings() {
    // defense against typo'd rule IDs drifting: the catalog in
    // docs/ANALYSIS.md is the source of truth; anything emitted by the
    // fixture sweep must be in it
    let catalog = [
        "AR001", "AR002", "AR003", "AR004", "AR005", "AR006", "AR007", "AR008", "AR009",
        "AR010", "CK001", "CK002", "CK003", "CK004", "CF001", "CF002", "CF003", "CF004",
        "LN000", "LN001", "LN002", "LN003", "LN004", "LN005", "LN006", "MM001", "MM002",
        "MM003", "MM004", "MM005",
    ];
    let mut findings = Vec::new();
    for dir in ["clean", "missing_accum", "bad_shape", "dtype_flip"] {
        findings.extend(check_artifacts(&fixture(dir)));
    }
    findings.extend(check_checkpoint(&fixture("truncated.rvt"), &fixture("clean/sft")));
    for dir in ["clean", "mm_inflated", "mm_dropped_alias", "mm_double_donation"] {
        findings.extend(check_hlo_mem(&fixture(dir), &HloMemOpts::default()).0);
    }
    for f in &findings {
        assert!(catalog.contains(&f.rule), "rule {} not in the documented catalog", f.rule);
    }
}
