//! Wire-protocol robustness: arbitrary and mutated bytes through the
//! NDJSON request path must never panic, must produce a well-formed
//! `error_json` reply when rejected, and valid requests must round-trip
//! exactly. The lazy hot path carries two agreement contracts pinned
//! here: `Request::from_line_fast` must equal `Request::from_line` on
//! every accepted line, and `Json::get_path` must equal a full parse +
//! `get` walk on every input the parser accepts. A committed seed
//! corpus (`tests/fixtures/wire_corpus.txt`) pins the regression cases;
//! the property tests explore around them.

use revffn::serve::protocol::{error_json, Priority, Request};
use revffn::util::json::{self, Json, ObjBuilder};
use revffn::util::prop::{gen, prop_check};
use revffn::util::rng::Rng;

/// The invariant every hostile line must satisfy: parsing returns (no
/// panic — the call itself proves that), a rejection converts into an
/// `error_json` reply that is itself valid JSON with `ok:false`, and
/// the lazy dispatcher (`Request::from_line_fast`) agrees with the full
/// parser on every line the full parser accepts.
fn survives(line: &str) -> bool {
    // calling the lazy path first proves it never panics, accepted or not
    let fast = Request::from_line_fast(line);
    match Request::from_line(line) {
        Ok(req) => {
            // accepted input must re-serialize and re-parse to itself,
            // and the hot path must have produced the identical request
            matches!(Request::from_line(&req.to_line()), Ok(back) if back == req)
                && matches!(fast, Ok(f) if f == req)
        }
        Err(e) => {
            let reply = error_json(&e.to_string()).to_string();
            match json::parse(&reply) {
                Ok(j) => matches!(j.bool_of("ok"), Ok(false)) && j.str_of("error").is_ok(),
                Err(_) => false,
            }
        }
    }
}

/// The paths the serve hot path actually scans, plus a nested one.
const HOT_PATHS: &[&[&str]] = &[
    &["cmd"],
    &["job"],
    &["name"],
    &["after_seq"],
    &["from"],
    &["limit"],
    &["follow"],
    &["priority"],
    &["tenant"],
    &["deadline_ms"],
    &["config", "method"],
];

/// `Json::get_path` agreement contract: on every input the full parser
/// accepts, the lazy scan must return exactly what walking the parsed
/// tree with `Json::get` would — including duplicate-key last-wins and
/// type mismatches along the path. On rejected input it must simply not
/// panic (its result is unspecified there — it skips what it never
/// validates).
fn paths_agree(text: &str) -> bool {
    let tree = json::parse(text);
    for path in HOT_PATHS {
        let lazy = Json::get_path(text, path);
        let Ok(ref t) = tree else { continue };
        let mut eager = Some(t);
        for key in *path {
            eager = eager.and_then(|v| v.get(key));
        }
        match (lazy, eager) {
            (Ok(l), e) if l.as_ref() == e => {}
            (got, want) => {
                eprintln!("path {path:?} on {text:?}: lazy {got:?} != eager {want:?}");
                return false;
            }
        }
    }
    true
}

#[test]
fn corpus_cases_never_panic_and_reject_cleanly() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/wire_corpus.txt");
    let text = std::fs::read_to_string(path).unwrap();
    let mut cases = 0;
    for line in text.lines() {
        if line.trim().is_empty() || line.starts_with('#') {
            continue;
        }
        cases += 1;
        assert!(survives(line), "corpus case failed invariant: {line:?}");
        assert!(paths_agree(line), "corpus case broke get_path agreement: {line:?}");
    }
    assert!(cases >= 25, "corpus unexpectedly small: {cases} cases");
    // the blank-line case, explicitly (corpus readers skip blank rows)
    assert!(survives(""));
    assert!(survives("   \t  "));
    assert!(paths_agree("") && paths_agree("   \t  "));
}

#[test]
fn prop_arbitrary_text_never_panics() {
    prop_check("wire-arbitrary-text", 300, 23,
        |rng| gen::string(rng, 120),
        |s| survives(s));
}

#[test]
fn prop_arbitrary_jsonish_never_panics() {
    // bias toward JSON punctuation so the parser gets past byte 0
    prop_check("wire-jsonish", 300, 29,
        |rng| {
            let n = rng.gen_range(0..100);
            (0..n)
                .map(|_| {
                    let jsonish = b"{}[]\",:0123456789.eE+-truefalsnl ";
                    jsonish[rng.gen_range(0..jsonish.len())] as char
                })
                .collect::<String>()
        },
        |s| survives(s));
}

fn random_request(rng: &mut Rng) -> Request {
    let job = format!("job-{}", rng.gen_range(0..100));
    match rng.gen_range(0..6) {
        0 => Request::Submit {
            config: ObjBuilder::new()
                .str("method", "revffn")
                .num("eval_every", rng.gen_range(0..50) as f64)
                .build(),
            name: if rng.gen_range(0..2) == 0 { None } else { Some(job) },
            priority: match rng.gen_range(0..3) {
                0 => Priority::Batch,
                1 => Priority::Normal,
                _ => Priority::Interactive,
            },
            tenant: if rng.gen_range(0..2) == 0 {
                None
            } else {
                Some(format!("tenant-{}", rng.gen_range(0..5)))
            },
            deadline_ms: if rng.gen_range(0..2) == 0 {
                None
            } else {
                Some(rng.gen_range(0..600_000) as u64)
            },
        },
        1 => Request::Status { job: if rng.gen_range(0..2) == 0 { None } else { Some(job) } },
        2 => Request::Events {
            job,
            from: rng.gen_range(0..10_000) as u64,
            limit: if rng.gen_range(0..2) == 0 {
                None
            } else {
                Some(rng.gen_range(1..5_000) as u64)
            },
            follow: rng.gen_range(0..2) == 0,
        },
        3 => Request::Cancel { job },
        4 => Request::Resume { job },
        _ => Request::Shutdown,
    }
}

#[test]
fn prop_get_path_agrees_with_full_parser() {
    // arbitrary text: agreement holds trivially on rejects (no panic)
    // and exactly on the occasional accept
    prop_check("get-path-arbitrary", 300, 41,
        |rng| gen::string(rng, 120),
        |s| paths_agree(s));
    // jsonish text parses much more often — this is where the accept
    // branch of the agreement contract actually gets exercised
    prop_check("get-path-jsonish", 300, 43,
        |rng| {
            let n = rng.gen_range(0..100);
            (0..n)
                .map(|_| {
                    let jsonish = b"{}[]\",:0123456789.eE+-truefalsnl ";
                    jsonish[rng.gen_range(0..jsonish.len())] as char
                })
                .collect::<String>()
        },
        |s| paths_agree(s));
    // serialized real requests: every one parses, so agreement is
    // checked on the exact shapes the serve hot path sees
    prop_check("get-path-requests", 200, 47,
        |rng| random_request(rng).to_line(),
        |s| paths_agree(s));
}

#[test]
fn prop_valid_requests_roundtrip() {
    prop_check("wire-roundtrip", 200, 31,
        |rng| random_request(rng),
        |req| matches!(Request::from_line(&req.to_line()), Ok(back) if back == *req));
}

#[test]
fn prop_mutated_valid_lines_never_panic() {
    prop_check("wire-mutation", 300, 37,
        |rng| {
            let line = random_request(rng).to_line();
            let mut bytes = line.into_bytes();
            for _ in 0..rng.gen_range(1..4) {
                if bytes.is_empty() {
                    break;
                }
                let pos = rng.gen_range(0..bytes.len());
                match rng.gen_range(0..3) {
                    0 => bytes[pos] = 0x20 + (rng.gen_range(0..0x5f) as u8),
                    1 => {
                        bytes.remove(pos);
                    }
                    _ => bytes.insert(pos, b"{}[]\","[rng.gen_range(0..6)]),
                }
            }
            String::from_utf8_lossy(&bytes).into_owned()
        },
        |s| survives(s) && paths_agree(s));
}

#[test]
fn deep_nesting_is_a_parse_error_not_a_crash() {
    // a hostile peer can send unbounded `[[[[…` — the codec's recursion
    // cap (util::json::MAX_DEPTH) must turn that into Error::Parse long
    // before the handler thread's stack is at risk
    for n in [200usize, 100_000] {
        let line = format!("{}{}", "[".repeat(n), "]".repeat(n));
        let err = Request::from_line(&line).unwrap_err();
        assert!(err.to_string().contains("nesting"), "unexpected error: {err}");
        assert!(survives(&line));
        // same payload smuggled inside an otherwise-valid submit
        let smuggled = format!(r#"{{"cmd":"submit","config":{{"x":{}{}}}}}"#,
            "[".repeat(n), "]".repeat(n));
        assert!(survives(&smuggled));
    }
}

#[test]
fn error_replies_are_single_line_json() {
    // NDJSON framing: a reply must never contain a raw newline, even
    // when the rejected input did
    let e = Request::from_line("{\"cmd\":\n\"nope\"").unwrap_err();
    let reply = error_json(&e.to_string()).to_string();
    assert!(!reply.contains('\n'), "reply broke NDJSON framing: {reply:?}");
    assert!(matches!(json::parse(&reply).unwrap().bool_of("ok"), Ok(false)));
    // and a rejected-but-parseable line too
    let j: Json = json::parse("{\"cmd\":\"nope\"}").unwrap();
    let e = Request::from_json(&j).unwrap_err();
    assert!(!error_json(&e.to_string()).to_string().contains('\n'));
}
