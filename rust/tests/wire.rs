//! Wire-protocol robustness: arbitrary and mutated bytes through the
//! NDJSON request path must never panic, must produce a well-formed
//! `error_json` reply when rejected, and valid requests must round-trip
//! exactly. A committed seed corpus (`tests/fixtures/wire_corpus.txt`)
//! pins the regression cases; the property tests explore around them.

use revffn::serve::protocol::{error_json, Request};
use revffn::util::json::{self, Json, ObjBuilder};
use revffn::util::prop::{gen, prop_check};
use revffn::util::rng::Rng;

/// The invariant every hostile line must satisfy: parsing returns (no
/// panic — the call itself proves that), and a rejection converts into
/// an `error_json` reply that is itself valid JSON with `ok:false`.
fn survives(line: &str) -> bool {
    match Request::from_line(line) {
        Ok(req) => {
            // accepted input must re-serialize and re-parse to itself
            matches!(Request::from_line(&req.to_line()), Ok(back) if back == req)
        }
        Err(e) => {
            let reply = error_json(&e.to_string()).to_string();
            match json::parse(&reply) {
                Ok(j) => matches!(j.bool_of("ok"), Ok(false)) && j.str_of("error").is_ok(),
                Err(_) => false,
            }
        }
    }
}

#[test]
fn corpus_cases_never_panic_and_reject_cleanly() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/wire_corpus.txt");
    let text = std::fs::read_to_string(path).unwrap();
    let mut cases = 0;
    for line in text.lines() {
        if line.trim().is_empty() || line.starts_with('#') {
            continue;
        }
        cases += 1;
        assert!(survives(line), "corpus case failed invariant: {line:?}");
    }
    assert!(cases >= 25, "corpus unexpectedly small: {cases} cases");
    // the blank-line case, explicitly (corpus readers skip blank rows)
    assert!(survives(""));
    assert!(survives("   \t  "));
}

#[test]
fn prop_arbitrary_text_never_panics() {
    prop_check("wire-arbitrary-text", 300, 23,
        |rng| gen::string(rng, 120),
        |s| survives(s));
}

#[test]
fn prop_arbitrary_jsonish_never_panics() {
    // bias toward JSON punctuation so the parser gets past byte 0
    prop_check("wire-jsonish", 300, 29,
        |rng| {
            let n = rng.gen_range(0..100);
            (0..n)
                .map(|_| {
                    let jsonish = b"{}[]\",:0123456789.eE+-truefalsnl ";
                    jsonish[rng.gen_range(0..jsonish.len())] as char
                })
                .collect::<String>()
        },
        |s| survives(s));
}

fn random_request(rng: &mut Rng) -> Request {
    let job = format!("job-{}", rng.gen_range(0..100));
    match rng.gen_range(0..6) {
        0 => Request::Submit {
            config: ObjBuilder::new()
                .str("method", "revffn")
                .num("eval_every", rng.gen_range(0..50) as f64)
                .build(),
            name: if rng.gen_range(0..2) == 0 { None } else { Some(job) },
        },
        1 => Request::Status { job: if rng.gen_range(0..2) == 0 { None } else { Some(job) } },
        2 => Request::Events {
            job,
            from: rng.gen_range(0..10_000) as u64,
            follow: rng.gen_range(0..2) == 0,
        },
        3 => Request::Cancel { job },
        4 => Request::Resume { job },
        _ => Request::Shutdown,
    }
}

#[test]
fn prop_valid_requests_roundtrip() {
    prop_check("wire-roundtrip", 200, 31,
        |rng| random_request(rng),
        |req| matches!(Request::from_line(&req.to_line()), Ok(back) if back == *req));
}

#[test]
fn prop_mutated_valid_lines_never_panic() {
    prop_check("wire-mutation", 300, 37,
        |rng| {
            let line = random_request(rng).to_line();
            let mut bytes = line.into_bytes();
            for _ in 0..rng.gen_range(1..4) {
                if bytes.is_empty() {
                    break;
                }
                let pos = rng.gen_range(0..bytes.len());
                match rng.gen_range(0..3) {
                    0 => bytes[pos] = 0x20 + (rng.gen_range(0..0x5f) as u8),
                    1 => {
                        bytes.remove(pos);
                    }
                    _ => bytes.insert(pos, b"{}[]\","[rng.gen_range(0..6)]),
                }
            }
            String::from_utf8_lossy(&bytes).into_owned()
        },
        |s| survives(s));
}

#[test]
fn deep_nesting_is_a_parse_error_not_a_crash() {
    // a hostile peer can send unbounded `[[[[…` — the codec's recursion
    // cap (util::json::MAX_DEPTH) must turn that into Error::Parse long
    // before the handler thread's stack is at risk
    for n in [200usize, 100_000] {
        let line = format!("{}{}", "[".repeat(n), "]".repeat(n));
        let err = Request::from_line(&line).unwrap_err();
        assert!(err.to_string().contains("nesting"), "unexpected error: {err}");
        assert!(survives(&line));
        // same payload smuggled inside an otherwise-valid submit
        let smuggled = format!(r#"{{"cmd":"submit","config":{{"x":{}{}}}}}"#,
            "[".repeat(n), "]".repeat(n));
        assert!(survives(&smuggled));
    }
}

#[test]
fn error_replies_are_single_line_json() {
    // NDJSON framing: a reply must never contain a raw newline, even
    // when the rejected input did
    let e = Request::from_line("{\"cmd\":\n\"nope\"").unwrap_err();
    let reply = error_json(&e.to_string()).to_string();
    assert!(!reply.contains('\n'), "reply broke NDJSON framing: {reply:?}");
    assert!(matches!(json::parse(&reply).unwrap().bool_of("ok"), Ok(false)));
    // and a rejected-but-parseable line too
    let j: Json = json::parse("{\"cmd\":\"nope\"}").unwrap();
    let e = Request::from_json(&j).unwrap_err();
    assert!(!error_json(&e.to_string()).to_string().contains('\n'));
}
