"""CI smoke tests for the `revffn serve` control plane.

Speaks the NDJSON wire protocol (docs/SERVE.md) over plain sockets.
Two modes:

* default ("cancel"): submit a longish job, stream a handful of its
  StepEvents on a second connection, cancel it mid-run, confirm the
  event stream terminates with a `done` marker in state `cancelled`,
  then shut the server down.
* "chaos": the server was started with an injected execute fault
  (REVFFN_FAULTS / --faults, docs/ROBUSTNESS.md). Submit a short
  snapshotting job, follow its events to the end, and assert the
  supervisor retried it (status reports attempts >= 1) and it still
  FINISHED — the fault is absorbed, not surfaced.

Usage: serve_smoke.py HOST PORT [cancel|chaos]
"""

import json
import socket
import sys
import time

HOST, PORT = sys.argv[1], int(sys.argv[2])
MODE = sys.argv[3] if len(sys.argv) > 3 else "cancel"
DEADLINE = time.time() + 120


def connect():
    last = None
    while time.time() < DEADLINE:
        try:
            s = socket.create_connection((HOST, PORT), timeout=60)
            s.settimeout(60)
            return s
        except OSError as e:  # server still booting
            last = e
            time.sleep(0.5)
    raise SystemExit(f"could not connect to {HOST}:{PORT}: {last}")


def send(sock, obj):
    sock.sendall((json.dumps(obj) + "\n").encode())


def lines(sock):
    buf = b""
    while True:
        chunk = sock.recv(4096)
        if not chunk:
            return
        buf += chunk
        while b"\n" in buf:
            line, buf = buf.split(b"\n", 1)
            if line.strip():
                yield json.loads(line)


def submit(control, control_lines, name, config):
    send(control, {"cmd": "submit", "name": name, "config": config})
    resp = next(control_lines)
    assert resp.get("ok"), f"submit failed: {resp}"
    assert resp.get("admitted"), f"job not admitted: {resp}"
    print(f"submitted {resp['job']} (peak {resp['peak_gb']:.4f} GB)")
    return resp["job"]


def shutdown(control, control_lines):
    send(control, {"cmd": "shutdown"})
    resp = next(control_lines)
    assert resp.get("ok"), f"shutdown failed: {resp}"


def check_metrics(control, control_lines):
    """The `metrics` verb must answer a Prometheus-parseable exposition
    with liveness (steps_total > 0) and the scheduler gauge families
    (docs/OBSERVABILITY.md)."""
    send(control, {"cmd": "metrics"})
    resp = next(control_lines)
    assert resp.get("ok") and resp.get("kind") == "metrics", f"bad metrics reply: {resp}"
    assert resp.get("steps_total", 0) > 0, f"metrics reports no steps: {resp}"
    body = resp["body"]
    for needle in (
        "# TYPE revffn_steps_total counter",
        "revffn_stage_seconds",
        "revffn_tenant_queue_depth",
        "revffn_jobs{state=",
        "revffn_budget_gb",
    ):
        assert needle in body, f"missing {needle!r} in exposition:\n{body[:600]}"
    for line in body.splitlines():
        if not line or line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        assert name, f"unparseable sample line: {line!r}"
        if value not in ("+Inf", "-Inf", "NaN"):
            float(value)  # raises on a malformed sample
    print(f"metrics scrape ok: steps_total={resp['steps_total']}")


def cancel_mode(control, control_lines):
    job = submit(control, control_lines, "smoke", {
        "method": "revffn",
        "eval_every": 0,
        "eval_batches": 1,
        "schedule": {"stage1_steps": 2, "stage2_steps": 200},
        "data": {"pretrain_steps": 0, "n_train": 48, "n_eval": 16},
    })

    events = connect()
    send(events, {"cmd": "events", "job": job, "from": 0, "follow": True})
    seen_steps = 0
    cancelled = False
    for ev in lines(events):
        if ev.get("done"):
            assert cancelled, f"stream ended before cancel: {ev}"
            assert ev["state"] == "cancelled", f"unexpected terminal state: {ev}"
            print(f"event stream terminated: {ev}")
            break
        if ev.get("type") == "step":
            seen_steps += 1
            print(f"  step {ev['step']} loss {ev['loss']:.4f}")
        if seen_steps >= 3 and not cancelled:
            send(control, {"cmd": "cancel", "job": job})
            resp = next(control_lines)
            assert resp.get("ok") and resp.get("cancelled"), f"cancel failed: {resp}"
            cancelled = True
            print("cancelled mid-run")
    else:
        raise SystemExit("event stream closed without a done marker")
    assert seen_steps >= 3, f"only {seen_steps} steps streamed"

    send(control, {"cmd": "status", "job": job})
    status = next(control_lines)
    assert status["jobs"][0]["state"] == "cancelled", f"bad status: {status}"
    print("status confirms cancellation")
    check_metrics(control, control_lines)
    shutdown(control, control_lines)
    print("serve smoke test passed")


def chaos_mode(control, control_lines):
    job = submit(control, control_lines, "chaos", {
        "method": "revffn",
        "eval_every": 0,
        "eval_batches": 1,
        "checkpoint_every": 2,
        "schedule": {"stage1_steps": 2, "stage2_steps": 4},
        "data": {"pretrain_steps": 0, "n_train": 48, "n_eval": 16},
    })

    events = connect()
    send(events, {"cmd": "events", "job": job, "from": 0, "follow": True})
    for ev in lines(events):
        if ev.get("type") == "step":
            print(f"  step {ev['step']} loss {ev['loss']:.4f}")
        if ev.get("done"):
            assert ev["state"] == "finished", f"fault not absorbed: {ev}"
            print(f"event stream terminated: {ev}")
            break
    else:
        raise SystemExit("event stream closed without a done marker")

    send(control, {"cmd": "status", "job": job})
    status = next(control_lines)
    row = status["jobs"][0]
    assert row["state"] == "finished", f"bad status: {status}"
    assert row.get("attempts", 0) >= 1, \
        f"the injected fault must have forced a supervised retry: {row}"
    print(f"job retried {row['attempts']} time(s) and finished")
    check_metrics(control, control_lines)
    shutdown(control, control_lines)
    print("serve chaos smoke test passed")


control = connect()
control_lines = lines(control)
if MODE == "chaos":
    chaos_mode(control, control_lines)
else:
    cancel_mode(control, control_lines)
