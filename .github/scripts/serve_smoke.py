"""CI smoke test for the `revffn serve` control plane.

Speaks the NDJSON wire protocol (docs/SERVE.md) over plain sockets:
submit a longish job, stream a handful of its StepEvents on a second
connection, cancel it mid-run, confirm the event stream terminates with
a `done` marker in state `cancelled`, then shut the server down.

Usage: serve_smoke.py HOST PORT
"""

import json
import socket
import sys
import time

HOST, PORT = sys.argv[1], int(sys.argv[2])
DEADLINE = time.time() + 120


def connect():
    last = None
    while time.time() < DEADLINE:
        try:
            s = socket.create_connection((HOST, PORT), timeout=60)
            s.settimeout(60)
            return s
        except OSError as e:  # server still booting
            last = e
            time.sleep(0.5)
    raise SystemExit(f"could not connect to {HOST}:{PORT}: {last}")


def send(sock, obj):
    sock.sendall((json.dumps(obj) + "\n").encode())


def lines(sock):
    buf = b""
    while True:
        chunk = sock.recv(4096)
        if not chunk:
            return
        buf += chunk
        while b"\n" in buf:
            line, buf = buf.split(b"\n", 1)
            if line.strip():
                yield json.loads(line)


control = connect()
control_lines = lines(control)

send(control, {
    "cmd": "submit",
    "name": "smoke",
    "config": {
        "method": "revffn",
        "eval_every": 0,
        "eval_batches": 1,
        "schedule": {"stage1_steps": 2, "stage2_steps": 200},
        "data": {"pretrain_steps": 0, "n_train": 48, "n_eval": 16},
    },
})
resp = next(control_lines)
assert resp.get("ok"), f"submit failed: {resp}"
assert resp.get("admitted"), f"job not admitted: {resp}"
job = resp["job"]
print(f"submitted {job} (peak {resp['peak_gb']:.4f} GB)")

events = connect()
send(events, {"cmd": "events", "job": job, "from": 0, "follow": True})
seen_steps = 0
cancelled = False
for ev in lines(events):
    if ev.get("done"):
        assert cancelled, f"stream ended before cancel: {ev}"
        assert ev["state"] == "cancelled", f"unexpected terminal state: {ev}"
        print(f"event stream terminated: {ev}")
        break
    if ev.get("type") == "step":
        seen_steps += 1
        print(f"  step {ev['step']} loss {ev['loss']:.4f}")
    if seen_steps >= 3 and not cancelled:
        send(control, {"cmd": "cancel", "job": job})
        resp = next(control_lines)
        assert resp.get("ok") and resp.get("cancelled"), f"cancel failed: {resp}"
        cancelled = True
        print("cancelled mid-run")
else:
    raise SystemExit("event stream closed without a done marker")
assert seen_steps >= 3, f"only {seen_steps} steps streamed"

send(control, {"cmd": "status", "job": job})
status = next(control_lines)
assert status["jobs"][0]["state"] == "cancelled", f"bad status: {status}"
print("status confirms cancellation")

send(control, {"cmd": "shutdown"})
resp = next(control_lines)
assert resp.get("ok"), f"shutdown failed: {resp}"
print("serve smoke test passed")
