//! Drive a running `revffn serve` instance: submit two concurrent
//! fine-tuning jobs (RevFFN + SFT), stream both NDJSON event feeds as
//! they interleave on the shared device, then print the final status
//! table (including each job's admission price).
//!
//!     # terminal 1
//!     cargo run --release -- serve --artifacts artifacts/tiny --budget-gb 8
//!     # terminal 2
//!     cargo run --release --example serve_client -- [HOST:PORT] [--shutdown]
//!
//! The wire protocol is documented in docs/SERVE.md.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use revffn::serve::protocol::Request;
use revffn::util::json::{self, Json};

/// Bridge the crate's `Result` into anyhow (the binary edge).
fn ok<T>(r: revffn::Result<T>) -> anyhow::Result<T> {
    r.map_err(|e| anyhow::anyhow!("{e}"))
}

fn send(stream: &mut TcpStream, req: &Request) -> anyhow::Result<()> {
    let mut line = req.to_line();
    line.push('\n');
    stream.write_all(line.as_bytes())?;
    stream.flush()?;
    Ok(())
}

fn read_line(reader: &mut BufReader<TcpStream>) -> anyhow::Result<Json> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}: {line}"))
}

/// Stream one job's events on its own connection, printing each line
/// with a job prefix, until the server sends the `done` marker.
fn follow_events(addr: &str, job: String) -> anyhow::Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    send(&mut stream, &Request::Events { job: job.clone(), from: 0, follow: true })?;
    let mut reader = BufReader::new(stream.try_clone()?);
    loop {
        let j = read_line(&mut reader)?;
        if j.get("done").and_then(Json::as_bool).unwrap_or(false) {
            println!("[{job}] done ({})", ok(j.str_of("state"))?);
            return Ok(());
        }
        let kind = j.str_of("type").unwrap_or_default();
        match kind.as_str() {
            "phase_started" => println!(
                "[{job}] phase {} ({}) — {} steps",
                ok(j.u64_of("phase"))?,
                ok(j.str_of("label"))?,
                ok(j.u64_of("steps"))?
            ),
            "step" => println!(
                "[{job}] step {:>3} loss {:.4}",
                ok(j.u64_of("step"))?,
                j.f64_of("loss").unwrap_or(f64::NAN)
            ),
            "eval" => println!(
                "[{job}] eval @ {} loss {:.4}",
                ok(j.u64_of("step"))?,
                j.f64_of("eval_loss").unwrap_or(f64::NAN)
            ),
            "phase_finished" => println!("[{job}] phase {} finished", ok(j.u64_of("phase"))?),
            _ => println!("[{job}] {j}"),
        }
    }
}

fn submit(
    reader: &mut BufReader<TcpStream>,
    stream: &mut TcpStream,
    config: &str,
    name: &str,
) -> anyhow::Result<String> {
    let req = Request::Submit { config: ok(json::parse(config))?, name: Some(name.into()) };
    send(stream, &req)?;
    let resp = read_line(reader)?;
    if !ok(resp.bool_of("ok"))? {
        anyhow::bail!("submit {name}: {}", resp.str_of("error").unwrap_or_default());
    }
    let id = ok(resp.str_of("job"))?;
    println!(
        "submitted {name} as {id}: admitted={} peak {:.4} GB",
        resp.bool_of("admitted").unwrap_or(false),
        resp.f64_of("peak_gb").unwrap_or(f64::NAN)
    );
    Ok(id)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let addr = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7433".into());
    let shutdown = args.iter().any(|a| a == "--shutdown");

    let mut control = TcpStream::connect(&addr)?;
    let mut reader = BufReader::new(control.try_clone()?);
    println!("== submitting two concurrent jobs to {addr} ==");
    let job_a = submit(
        &mut reader,
        &mut control,
        r#"{"method":"revffn","eval_every":0,"eval_batches":1,
            "schedule":{"stage1_steps":2,"stage2_steps":6},
            "data":{"pretrain_steps":0,"n_train":64,"n_eval":16}}"#,
        "revffn-demo",
    )?;
    let job_b = submit(
        &mut reader,
        &mut control,
        r#"{"method":"sft","eval_every":0,"eval_batches":1,
            "schedule":{"stage2_steps":6},
            "data":{"pretrain_steps":0,"n_train":64,"n_eval":16}}"#,
        "sft-demo",
    )?;

    // stream both feeds concurrently — the interleaving you see is the
    // scheduler's round-robin over the shared device
    let addr_a = addr.clone();
    let addr_b = addr.clone();
    let ta = std::thread::spawn(move || follow_events(&addr_a, job_a));
    let tb = std::thread::spawn(move || follow_events(&addr_b, job_b));
    ta.join().expect("job-a follower panicked")?;
    tb.join().expect("job-b follower panicked")?;

    send(&mut control, &Request::Status { job: None })?;
    let status = read_line(&mut reader)?;
    println!(
        "\nbudget {:.3} GB, committed {:.3} GB",
        ok(status.f64_of("budget_gb"))?,
        ok(status.f64_of("committed_gb"))?
    );
    for row in ok(status.arr_of("jobs"))? {
        println!(
            "  {}  {:<12} {:<9} peak {:.4} GB  steps {}  last loss {:.4}",
            ok(row.str_of("id"))?,
            ok(row.str_of("name"))?,
            ok(row.str_of("state"))?,
            ok(row.f64_of("peak_gb"))?,
            ok(row.u64_of("steps_done"))?,
            row.f64_of("last_loss").unwrap_or(f64::NAN)
        );
    }

    if shutdown {
        send(&mut control, &Request::Shutdown)?;
        let _ = read_line(&mut reader)?;
        println!("server asked to shut down");
    }
    Ok(())
}
