//! Drive a running `revffn serve` instance: submit two concurrent
//! fine-tuning jobs — an `interactive` RevFFN job with a deadline and a
//! `batch` SFT job under a different tenant — then follow both NDJSON
//! event feeds with **cursor-paginated** `events` requests (the
//! `next_cursor` chain from docs/SERVE.md) and print the final status
//! table, including each job's admission price and scheduling identity.
//!
//!     # terminal 1
//!     cargo run --release -- serve --artifacts artifacts/tiny --budget-gb 8
//!     # terminal 2
//!     cargo run --release --example serve_client -- [HOST:PORT] [--shutdown]
//!
//! The wire protocol is documented in docs/SERVE.md.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use revffn::serve::protocol::{Priority, Request};
use revffn::util::json::{self, Json};

/// Bridge the crate's `Result` into anyhow (the binary edge).
fn ok<T>(r: revffn::Result<T>) -> anyhow::Result<T> {
    r.map_err(|e| anyhow::anyhow!("{e}"))
}

fn send(stream: &mut TcpStream, req: &Request) -> anyhow::Result<()> {
    let mut line = req.to_line();
    line.push('\n');
    stream.write_all(line.as_bytes())?;
    stream.flush()?;
    Ok(())
}

fn read_line(reader: &mut BufReader<TcpStream>) -> anyhow::Result<Json> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}: {line}"))
}

fn print_event(job: &str, j: &Json) -> anyhow::Result<()> {
    let kind = j.str_of("type").unwrap_or_default();
    match kind.as_str() {
        "phase_started" => println!(
            "[{job}] phase {} ({}) — {} steps",
            ok(j.u64_of("phase"))?,
            ok(j.str_of("label"))?,
            ok(j.u64_of("steps"))?
        ),
        "step" => println!(
            "[{job}] step {:>3} loss {:.4}",
            ok(j.u64_of("step"))?,
            j.f64_of("loss").unwrap_or(f64::NAN)
        ),
        "eval" => println!(
            "[{job}] eval @ {} loss {:.4}",
            ok(j.u64_of("step"))?,
            j.f64_of("eval_loss").unwrap_or(f64::NAN)
        ),
        "phase_finished" => println!("[{job}] phase {} finished", ok(j.u64_of("phase"))?),
        _ => println!("[{job}] {j}"),
    }
    Ok(())
}

/// Follow one job's events by chaining paginated non-follow requests:
/// each page's `next_cursor` footer is the next request's `from`, so a
/// lost connection costs nothing — resubmit with the last cursor. Stops
/// once a footer reports `done` (terminal job, cursor at end of log).
fn follow_events_paged(addr: &str, job: String, page: u64) -> anyhow::Result<()> {
    let mut cursor = 0u64;
    loop {
        // one fresh connection per page: the cursor, not the socket,
        // carries the position
        let mut stream = TcpStream::connect(addr)?;
        send(
            &mut stream,
            &Request::Events { job: job.clone(), from: cursor, limit: Some(page), follow: false },
        )?;
        let mut reader = BufReader::new(stream);
        let mut progressed = false;
        loop {
            let j = read_line(&mut reader)?;
            if j.get("page").and_then(Json::as_bool).unwrap_or(false) {
                let next = ok(j.u64_of("next_cursor"))?;
                progressed = next > cursor;
                cursor = next;
                if ok(j.bool_of("done"))? {
                    println!("[{job}] done ({}) after {cursor} events", ok(j.str_of("state"))?);
                    return Ok(());
                }
                break;
            }
            print_event(&job, &j)?;
        }
        if !progressed {
            // caught up with a live job — poll instead of spinning
            std::thread::sleep(Duration::from_millis(100));
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn submit(
    reader: &mut BufReader<TcpStream>,
    stream: &mut TcpStream,
    config: &str,
    name: &str,
    priority: Priority,
    tenant: &str,
    deadline_ms: Option<u64>,
) -> anyhow::Result<String> {
    let req = Request::Submit {
        config: ok(json::parse(config))?,
        name: Some(name.into()),
        priority,
        tenant: Some(tenant.into()),
        deadline_ms,
    };
    send(stream, &req)?;
    let resp = read_line(reader)?;
    if !ok(resp.bool_of("ok"))? {
        anyhow::bail!("submit {name}: {}", resp.str_of("error").unwrap_or_default());
    }
    let id = ok(resp.str_of("job"))?;
    println!(
        "submitted {name} as {id}: admitted={} peak {:.4} GB priority={} tenant={}",
        resp.bool_of("admitted").unwrap_or(false),
        resp.f64_of("peak_gb").unwrap_or(f64::NAN),
        resp.str_of("priority").unwrap_or_default(),
        resp.str_of("tenant").unwrap_or_default()
    );
    Ok(id)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let addr = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7433".into());
    let shutdown = args.iter().any(|a| a == "--shutdown");

    let mut control = TcpStream::connect(&addr)?;
    let mut reader = BufReader::new(control.try_clone()?);
    println!("== submitting two concurrent jobs to {addr} ==");
    // the interactive job outranks the batch job at every quantum
    // boundary, so its steps come first in the interleaving below even
    // though both are admitted together
    let job_a = submit(
        &mut reader,
        &mut control,
        r#"{"method":"revffn","eval_every":0,"eval_batches":1,
            "schedule":{"stage1_steps":2,"stage2_steps":6},
            "data":{"pretrain_steps":0,"n_train":64,"n_eval":16}}"#,
        "revffn-demo",
        Priority::Interactive,
        "team-a",
        Some(60_000),
    )?;
    let job_b = submit(
        &mut reader,
        &mut control,
        r#"{"method":"sft","eval_every":0,"eval_batches":1,
            "schedule":{"stage2_steps":6},
            "data":{"pretrain_steps":0,"n_train":64,"n_eval":16}}"#,
        "sft-demo",
        Priority::Batch,
        "team-b",
        None,
    )?;

    // follow both feeds concurrently via cursor pagination (4 lines a
    // page) — the interleaving you see is the scheduler's
    // priority-then-round-robin over the shared device
    let addr_a = addr.clone();
    let addr_b = addr.clone();
    let ta = std::thread::spawn(move || follow_events_paged(&addr_a, job_a, 4));
    let tb = std::thread::spawn(move || follow_events_paged(&addr_b, job_b, 4));
    ta.join().expect("job-a follower panicked")?;
    tb.join().expect("job-b follower panicked")?;

    send(&mut control, &Request::Status { job: None })?;
    let status = read_line(&mut reader)?;
    println!(
        "\nbudget {:.3} GB, committed {:.3} GB",
        ok(status.f64_of("budget_gb"))?,
        ok(status.f64_of("committed_gb"))?
    );
    for row in ok(status.arr_of("jobs"))? {
        println!(
            "  {}  {:<12} {:<9} {:<11} {:<7} peak {:.4} GB  steps {}  last loss {:.4}",
            ok(row.str_of("id"))?,
            ok(row.str_of("name"))?,
            ok(row.str_of("state"))?,
            row.str_of("priority").unwrap_or_default(),
            row.str_of("tenant").unwrap_or_default(),
            ok(row.f64_of("peak_gb"))?,
            ok(row.u64_of("steps_done"))?,
            row.f64_of("last_loss").unwrap_or(f64::NAN)
        );
    }

    if shutdown {
        send(&mut control, &Request::Shutdown)?;
        let _ = read_line(&mut reader)?;
        println!("server asked to shut down");
    }
    Ok(())
}
