//! End-to-end validation driver (DESIGN.md E6): fine-tune the MoE model
//! with RevFFN's full two-stage schedule on the synthetic Dolly-like
//! corpus, log the loss curve, and score the trained model on the
//! Table-2 benchmark suite.
//!
//! Drives the run through the step-granular `Run::step()` API — each
//! `StepEvent` streams out as it happens, which is how an external
//! scheduler or server would multiplex runs.
//!
//!     cargo run --release --example finetune_e2e -- [steps2] [steps1] [pretrain]
//!
//! Defaults: 170 stage-2 steps, 30 stage-1 steps, 60 LM pre-pass steps —
//! a few hundred optimizer steps total, as the reproduction protocol
//! requires. The loss curve lands in runs/e2e/metrics.jsonl and the
//! summary is recorded in EXPERIMENTS.md.

use revffn::config::RunConfig;
use revffn::coordinator::Trainer;
use revffn::engine::{Method, StepEvent};
use revffn::runtime::Device;

fn main() -> anyhow::Result<()> {
    let args: Vec<u64> = std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let stage2 = args.first().copied().unwrap_or(170);
    let stage1 = args.get(1).copied().unwrap_or(30);
    let pretrain = args.get(2).copied().unwrap_or(60);

    let mut cfg = RunConfig::default_tiny("artifacts/tiny");
    cfg.method = Method::Revffn;
    cfg.schedule.stage1_steps = stage1;
    cfg.schedule.stage2_steps = stage2;
    cfg.data.pretrain_steps = pretrain;
    cfg.eval_every = 25;
    cfg.out_dir = "runs/e2e".into();
    cfg.save_checkpoint = true;

    let device = Device::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "== RevFFN end-to-end: pre-pass {pretrain} + stage1 {stage1} + stage2 {stage2} steps =="
    );
    let mut trainer = Trainer::new(&device, cfg).map_err(|e| anyhow::anyhow!("{e}"))?;

    // stream the run event-by-event instead of blocking in run()
    let mut run = trainer.start().map_err(|e| anyhow::anyhow!("{e}"))?;
    while let Some(event) = run.step().map_err(|e| anyhow::anyhow!("{e}"))? {
        match event {
            StepEvent::PhaseStarted { label, steps, batch_size, seq_len, .. } => {
                println!("-- {label}: {steps} steps (batch {batch_size}x{seq_len})");
            }
            StepEvent::Step(rec) if rec.step % 10 == 0 => {
                println!(
                    "  stage{} step {:>4}  loss {:.4}  lr {:.2e}",
                    rec.stage, rec.step, rec.loss, rec.lr
                );
            }
            StepEvent::EvalPoint { step, eval_loss } => {
                println!("  eval @ step {step:>4}  loss {eval_loss:.4}");
            }
            StepEvent::PhaseFinished { stage, eval_loss, .. } => {
                println!("-- stage {stage} done (eval {eval_loss:.4})");
            }
            _ => {}
        }
    }
    let report = run.finish().map_err(|e| anyhow::anyhow!("{e}"))?;

    println!(
        "\nsummary: {} steps, train loss {:.4} -> {:.4}, eval {:.4}, {:.1} samples/s, wall {:.0}s",
        report.steps_run,
        report.first_loss,
        report.final_loss,
        report.eval_loss.unwrap_or(f32::NAN),
        report.median_samples_per_s,
        report.wall_time_s
    );
    assert!(
        report.final_loss < report.first_loss,
        "e2e validation failed: loss did not decrease"
    );

    let scores = trainer.bench_scores(32, 7).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "benchmarks: mmlu-like {:.1}%  gsm8k-like {:.1}%  multilingual-like {:.1}%  mtbench-like {:.2}",
        scores.mmlu_like, scores.gsm8k_like, scores.multilingual_like, scores.mtbench_like
    );
    println!("metrics written to {}", trainer.metrics_path().display());
    Ok(())
}
