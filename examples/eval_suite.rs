//! Eval-suite example: score an *untrained* vs a *briefly-trained* model
//! on the synthetic benchmark suite, demonstrating the Table-2 measuring
//! instrument itself (score discrimination, candidate scoring, the
//! language-B transfer probe).
//!
//!     cargo run --release --example eval_suite -- [train_steps]

use revffn::data::{encode_corpus, Batcher};
use revffn::engine::{Method, Session};

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(40);
    let mut session = Session::builder("artifacts/tiny")
        .method(Method::Revffn)
        .build()
        .map_err(|e| anyhow::anyhow!("{e} — did you run `make artifacts`?"))?;

    println!("== untrained model ==");
    let before = session.bench_scores(24, 7).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "  mmlu-like {:.1}%  gsm8k-like {:.1}%  multilingual-like {:.1}%  mtbench-like {:.2}",
        before.mmlu_like, before.gsm8k_like, before.multilingual_like, before.mtbench_like
    );
    println!("  (random-guess floor: mmlu {:.1}%, gsm8k 25.0%)", 100.0 / 8.0);

    println!("\n== training {steps} steps ==");
    let (b, s) = session.stepper.batch_shape();
    let samples = encode_corpus(&session.tokenizer, &session.corpus.train, s);
    let mut batcher = Batcher::new(samples, b, s, 0);
    for step in 0..steps {
        let batch = batcher.next_batch();
        let stats = session
            .stepper
            .train_step(&batch, 3e-4)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        if step % 10 == 0 {
            println!("  step {step}: loss {:.4}", stats.loss);
        }
    }

    println!("\n== after training ==");
    let after = session.bench_scores(24, 7).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "  mmlu-like {:.1}%  gsm8k-like {:.1}%  multilingual-like {:.1}%  mtbench-like {:.2}",
        after.mmlu_like, after.gsm8k_like, after.multilingual_like, after.mtbench_like
    );
    println!(
        "\nmtbench-like delta: {:+.2} (instruction quality must improve with training)",
        after.mtbench_like - before.mtbench_like
    );
    Ok(())
}
