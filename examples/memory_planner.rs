//! Memory-planner example: "will my fine-tuning run fit?"
//!
//! Walks the analytic VRAM model (the Table-1 engine) across methods,
//! sequence lengths and GPU budgets at real Qwen1.5-MoE-A2.7B geometry —
//! the tool a practitioner would use before renting a GPU.
//!
//!     cargo run --release --example memory_planner

use revffn::memory::{
    format_table, ordering_checks, table1_memory, Assumptions, Geometry, MemoryModel, Method,
};

fn main() {
    let geo = Geometry::qwen15_moe_a27b();
    println!(
        "Qwen1.5-MoE-A2.7B: {:.2}B params ({:.2}B + {:.0}M adapters as RevFFN)\n",
        geo.total_params() as f64 / 1e9,
        geo.total_params() as f64 / 1e9,
        (geo.total_params_revffn() - geo.total_params()) as f64 / 1e6,
    );

    // The paper's protocol: 80 GB H800, per-method maximized batch.
    for (name, assume) in [
        ("bf16 mixed precision (fp32 moments + master)", Assumptions::bf16_mixed()),
        ("paper-calibrated (bf16, 8-bit moments, no master)", Assumptions::paper_calibrated()),
    ] {
        let rows = table1_memory(geo.clone(), assume, 2048, 80.0, None);
        print!("{}", format_table(&rows, &format!("== {name} ==")));
        for (check, ok) in ordering_checks(&rows) {
            println!("  [{}] {check}", if ok { "ok" } else { "MISS" });
        }
        println!();
    }

    // Which GPUs can full-fine-tune this model with RevFFN?
    println!("== minimum GPU budget for full-parameter fine-tuning (seq 2048, batch 1) ==");
    let model = MemoryModel::new(geo.clone(), Assumptions::paper_calibrated());
    for m in [Method::SftCheckpoint, Method::Lomo, Method::Galore, Method::Revffn] {
        let need = model.peak_gb(m, 1, 2048);
        let fits: Vec<&str> = [("24GB-4090", 24.0), ("40GB-A100", 40.0), ("80GB-H800", 80.0)]
            .iter()
            .filter(|(_, gb)| need <= *gb)
            .map(|(n, _)| *n)
            .collect();
        println!("  {:<22} needs {need:>6.1} GB -> fits: {fits:?}", m.label());
    }

    // Sequence-length sweep: where does each method hit the 80 GB wall?
    println!("\n== max microbatch vs sequence length (80 GB budget, paper-calibrated) ==");
    print!("{:<22}", "Method");
    let seqs = [512u64, 1024, 2048, 4096, 8192];
    for s in seqs {
        print!(" {s:>7}");
    }
    println!();
    for m in Method::ALL {
        print!("{:<22}", m.label());
        for s in seqs {
            print!(" {:>7}", model.max_batch(m, s, 80.0));
        }
        println!();
    }
}
