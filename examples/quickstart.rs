//! Quickstart: load the RevFFN artifacts through the `Session` facade,
//! run a few reversible fine-tuning steps on a synthetic batch, and
//! verify the §3.1 reconstruction claim.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This exercises the full stack end to end: manifest parsing → blob
//! loading → PJRT compile → train_step execution → reversibility check.

use revffn::data::synthetic::CorpusConfig;
use revffn::data::{encode_corpus, Batcher};
use revffn::engine::{Method, Session};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts/tiny".to_string());

    // 1. One builder call replaces device + cache + artifact + tokenizer
    //    assembly (see `revffn::engine::Session`)
    let mut session = Session::builder(&artifacts)
        .method(Method::Revffn)
        .corpus(CorpusConfig { n_train: 256, ..Default::default() })
        .build()
        .map_err(|e| anyhow::anyhow!("{e} — did you run `make artifacts`?"))?;
    println!(
        "device: {} x{}",
        session.device.platform_name(),
        session.device.device_count()
    );
    let manifest = &session.stepper.artifact.manifest;
    println!(
        "model: {} ({} tensors, {}/{} params trainable)",
        manifest.model.name,
        manifest.tensors.len(),
        manifest.n_params_trainable,
        manifest.n_params_total,
    );

    // 2. Synthetic instruction data through the session's tokenizer
    let (b, s) = session.stepper.batch_shape();
    let samples = encode_corpus(&session.tokenizer, &session.corpus.train, s);
    let mut batcher = Batcher::new(samples, b, s, 0);

    // 3. A few reversible full-parameter optimizer steps
    println!("running 8 train steps (batch {b}x{s})…");
    let mut first = None;
    let mut last = 0.0;
    for step in 0..8 {
        let batch = batcher.next_batch();
        let stats = session
            .stepper
            .train_step(&batch, 3e-4)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        first.get_or_insert(stats.loss);
        last = stats.loss;
        println!(
            "  step {step}: loss {:.4}  grad-norm {:.2}  {:.0} ms",
            stats.loss,
            stats.grad_norm,
            stats.step_time_s * 1e3
        );
    }
    println!(
        "loss {:.4} -> {:.4} ({})",
        first.unwrap(),
        last,
        if last < first.unwrap() { "learning ✓" } else { "no movement yet" }
    );

    // 4. Reversibility: reconstruct inputs from outputs through the stack
    let (rec, prog) = session
        .program("reconstruct", "reconstruct")
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let trained = session
        .stepper
        .materialize_params()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut inputs = trained.to_literals().map_err(|e| anyhow::anyhow!("{e}"))?;
    let io = &rec.manifest.io;
    let tokens: Vec<i32> = (0..io.batch_size * io.seq_len)
        .map(|i| (i % 200) as i32 + 5)
        .collect();
    inputs.push(
        revffn::runtime::literal::i32_literal(&tokens, &[io.batch_size, io.seq_len])
            .map_err(|e| anyhow::anyhow!("{e}"))?,
    );
    let out = prog.run(&inputs).map_err(|e| anyhow::anyhow!("{e}"))?;
    let err = revffn::runtime::literal::scalar_to_f32(&out[0]).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "reversible reconstruction error (trained weights, 1 fixed-point iter): {err:.3e} — {}",
        if err < 5e-2 {
            "bounded ✓ (see `cargo bench --bench fig_reversibility` for the iteration sweep)"
        } else {
            "UNEXPECTEDLY LARGE"
        }
    );
    Ok(())
}
