//! Quickstart: load the RevFFN artifacts, run a few reversible fine-tuning
//! steps on a synthetic batch, and verify the §3.1 reconstruction claim.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This exercises the full stack end to end: manifest parsing → blob
//! loading → PJRT compile → train_step execution → reversibility check.

use revffn::data::synthetic::{Corpus, CorpusConfig};
use revffn::data::{encode_corpus, Batcher, Tokenizer};
use revffn::runtime::{Artifact, Device, ProgramCache, Stepper};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts/tiny".to_string());

    // 1. PJRT device + compiled programs
    let device = Device::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("device: {} x{}", device.platform_name(), device.device_count());
    let cache = ProgramCache::new();
    let artifact = Artifact::load(format!("{artifacts}/revffn_stage2"))
        .map_err(|e| anyhow::anyhow!("{e} — did you run `make artifacts`?"))?;
    println!(
        "model: {} ({} tensors, {}/{} params trainable)",
        artifact.manifest.model.name,
        artifact.manifest.tensors.len(),
        artifact.manifest.n_params_trainable,
        artifact.manifest.n_params_total,
    );
    let mut stepper =
        Stepper::new(&device, &cache, artifact).map_err(|e| anyhow::anyhow!("{e}"))?;

    // 2. Synthetic instruction data
    let corpus = Corpus::generate(CorpusConfig { n_train: 256, ..Default::default() });
    let tokenizer = Tokenizer::train(&corpus.train_text(), stepper.vocab_size())
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let (b, s) = stepper.batch_shape();
    let samples = encode_corpus(&tokenizer, &corpus.train, s);
    let mut batcher = Batcher::new(samples, b, s, 0);

    // 3. A few reversible full-parameter optimizer steps
    println!("running 8 train steps (batch {b}x{s})…");
    let mut first = None;
    let mut last = 0.0;
    for step in 0..8 {
        let batch = batcher.next_batch();
        let stats = stepper
            .train_step(&batch, 3e-4)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        first.get_or_insert(stats.loss);
        last = stats.loss;
        println!(
            "  step {step}: loss {:.4}  grad-norm {:.2}  {:.0} ms",
            stats.loss,
            stats.grad_norm,
            stats.step_time_s * 1e3
        );
    }
    println!(
        "loss {:.4} -> {:.4} ({})",
        first.unwrap(),
        last,
        if last < first.unwrap() { "learning ✓" } else { "no movement yet" }
    );

    // 4. Reversibility: reconstruct inputs from outputs through the stack
    let rec = Artifact::load(format!("{artifacts}/reconstruct"))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let prog = device
        .load_hlo_text(rec.hlo_path("reconstruct").map_err(|e| anyhow::anyhow!("{e}"))?)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let trained = stepper.materialize_params().map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut inputs = trained.to_literals().map_err(|e| anyhow::anyhow!("{e}"))?;
    let io = &rec.manifest.io;
    let tokens: Vec<i32> = (0..io.batch_size * io.seq_len)
        .map(|i| (i % 200) as i32 + 5)
        .collect();
    inputs.push(
        revffn::runtime::literal::i32_literal(&tokens, &[io.batch_size, io.seq_len])
            .map_err(|e| anyhow::anyhow!("{e}"))?,
    );
    let out = prog.run(&inputs).map_err(|e| anyhow::anyhow!("{e}"))?;
    let err = revffn::runtime::literal::scalar_to_f32(&out[0]).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "reversible reconstruction error (trained weights, 1 fixed-point iter): {err:.3e} — {}",
        if err < 5e-2 {
            "bounded ✓ (see `cargo bench --bench fig_reversibility` for the iteration sweep)"
        } else {
            "UNEXPECTEDLY LARGE"
        }
    );
    Ok(())
}
