"""L1 performance analysis: VMEM footprint + MXU utilization *estimates*
per Pallas kernel BlockSpec, at both the tiny test geometry and the real
Qwen1.5-MoE-A2.7B geometry.

interpret=True gives CPU-numpy timings that are NOT a TPU proxy, so the
optimization target is structural (DESIGN.md §Perf): block shapes that
(a) fit the ~16 MiB/core VMEM budget with double-buffering headroom and
(b) keep the MXU's 128x128 systolic array busy (tile dims that are
multiples of 128 on the contracted axes, enough arithmetic per byte).

Run:  python -m compile.kernel_analysis
"""

from __future__ import annotations

import dataclasses

from .configs import CONFIGS, ModelConfig

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM, TPUv4-class
MXU = 128                       # systolic array edge


@dataclasses.dataclass
class KernelEstimate:
    name: str
    block_desc: str
    vmem_bytes: int
    mxu_m: int      # effective tile dims feeding the MXU
    mxu_k: int
    mxu_n: int
    flops_per_byte: float

    @property
    def vmem_frac(self) -> float:
        return self.vmem_bytes / VMEM_BYTES

    @property
    def mxu_utilization(self) -> float:
        """Fraction of the 128x128 array covered by the tile (dims are
        padded up to the array edge — utilization = prod(min(d,128)/128
        over the two spatial axes) discounted by K-dim padding)."""
        um = min(self.mxu_m, MXU) / MXU
        un = min(self.mxu_n, MXU) / MXU
        uk = 1.0 if self.mxu_k % MXU == 0 or self.mxu_k >= MXU else self.mxu_k / MXU
        return um * un * uk


def analyze(cfg: ModelConfig, block_t: int = 128, block_q: int = 128,
            block_k: int = 128, block_f: int = 512,
            bf16: bool = True) -> list[KernelEstimate]:
    """Estimates for each kernel's working set at one grid step."""
    b = 2 if bf16 else 4
    d, dh = cfg.d_model, cfg.d_half
    f, fs, e = cfg.d_ff_expert, cfg.d_ff_shared, cfg.n_experts
    hd = cfg.head_dim
    out = []

    # moe_ffn: expert weight slab (f-chunked) + token tile + combine + out
    bf = min(block_f, f)
    w_bytes = 3 * d * bf * b
    t_bytes = block_t * (d + e + d) * b + block_t * bf * 4  # acc in f32
    out.append(KernelEstimate(
        "moe_ffn",
        f"(E,F,T)-grid, token tile {block_t}x{d}, weight slab d={d},bf={bf}",
        w_bytes + t_bytes,
        mxu_m=block_t, mxu_k=d, mxu_n=bf,
        flops_per_byte=(2 * block_t * d * bf * 3) / max(w_bytes + t_bytes, 1),
    ))

    # attention: q tile + full k/v + accumulators
    s = cfg.max_seq_len
    a_bytes = (block_q * hd + 2 * s * hd) * b + block_q * (hd + 2) * 4
    out.append(KernelEstimate(
        "attention",
        f"(BH,Q)-grid, q tile {block_q}x{hd}, kv {s}x{hd}, online softmax",
        a_bytes,
        mxu_m=block_q, mxu_k=hd, mxu_n=block_k,
        flops_per_byte=(4 * block_q * s * hd) / max(a_bytes, 1),
    ))

    # rmsnorm: row tile
    r_bytes = 2 * block_t * dh * b
    out.append(KernelEstimate(
        "rmsnorm", f"row tile {block_t}x{dh}", r_bytes,
        mxu_m=block_t, mxu_k=1, mxu_n=dh,
        flops_per_byte=(3 * block_t * dh) / max(r_bytes, 1),
    ))

    # router: token tile x experts
    ro_bytes = 2 * block_t * e * 4
    out.append(KernelEstimate(
        "router_topk", f"token tile {block_t}x{e}, k={cfg.top_k} argmax rounds",
        ro_bytes,
        mxu_m=block_t, mxu_k=1, mxu_n=e,
        flops_per_byte=(cfg.top_k * block_t * e) / max(ro_bytes, 1),
    ))
    return out


def report(cfg_name: str) -> str:
    cfg = CONFIGS[cfg_name]
    rows = analyze(cfg)
    lines = [f"== {cfg_name}: d={cfg.d_model} f={cfg.d_ff_expert} E={cfg.n_experts} "
             f"S={cfg.max_seq_len} (bf16 tiles, f32 accumulators) =="]
    lines.append(f"{'kernel':<12} {'VMEM':>10} {'%VMEM':>7} {'MXU util':>9} "
                 f"{'flops/B':>8}  block")
    for r in rows:
        lines.append(
            f"{r.name:<12} {r.vmem_bytes/1e6:>8.2f}MB {100*r.vmem_frac:>6.1f}% "
            f"{100*r.mxu_utilization:>8.1f}% {r.flops_per_byte:>8.1f}  {r.block_desc}"
        )
    return "\n".join(lines)


def main() -> None:
    for name in ("tiny", "qwen15_moe_a27b"):
        print(report(name))
        print()
    # block-shape sweep for moe_ffn at Qwen geometry (the §Perf L1 iteration)
    cfg = CONFIGS["qwen15_moe_a27b"]
    print("== moe_ffn (block_t, block_f) sweep at Qwen geometry (§Perf L1) ==")
    print(f"{'block_t':>8} {'block_f':>8} {'VMEM':>10} {'%VMEM':>7} {'MXU util':>9}")
    for bt in (64, 128, 256):
        for bfv in (256, 512, 1408):
            k = analyze(cfg, block_t=bt, block_f=bfv)[0]
            flag = " <= chosen" if (bt, bfv) == (128, 512) else ""
            print(f"{bt:>8} {bfv:>8} {k.vmem_bytes/1e6:>8.2f}MB {100*k.vmem_frac:>6.1f}% "
                  f"{100*k.mxu_utilization:>8.1f}%{flag}")


if __name__ == "__main__":
    main()
