"""AOT pipeline: lower every (method, config) step function to HLO text,
write initial-parameter blobs, and emit the manifest the Rust coordinator
reads. This is the ONLY place Python runs; after ``make artifacts`` the
Rust binary is self-contained.

HLO **text** is the interchange format — jax≥0.5 serialized protos carry
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts [--config tiny]
        [--methods sft,lora,...|all] [--pallas] [--batch B] [--seq S]
        [--analyze]    # embed XLA memory_analysis in manifests (Table 1 calib)

Layout:
    artifacts/<cfg>/blobs/{standard,revffn}.bin + peft_<m>.bin
    artifacts/<cfg>/<variant>/train_step.hlo.txt
    artifacts/<cfg>/<variant>/{grad,apply,accum}_step.hlo.txt + scale.hlo.txt
    artifacts/<cfg>/<variant>/forward.hlo.txt
    artifacts/<cfg>/<variant>/eval_step.hlo.txt
    artifacts/<cfg>/<variant>/manifest.json
    artifacts/<cfg>/reconstruct/reconstruct.hlo.txt (+ manifest)
where <variant> = method, with revffn split into revffn_stage1/_stage2.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import params as P
from .configs import CONFIGS, ModelConfig, TrainConfig
from .methods import ALL_VARIANTS, METHODS
from .model import revffn_reconstruct
from .trainstep import StepBuilder


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


def _blob_index(params: dict) -> dict[str, dict]:
    """name -> {shape, offset, nbytes} for one blob."""
    return {e["name"]: e for e in P.manifest_entries(params)}


def build_blobs(cfg: ModelConfig, tc: TrainConfig, out_dir: str, seed: int = 0):
    """Initial parameters. The standard model doubles as the 'pre-trained
    checkpoint' (the Rust trainer optionally runs a brief LM pre-pass to
    move it off random init — see DESIGN.md §Substitutions); the RevFFN
    model wraps those same weights (§3.2 plug-and-play)."""
    blob_dir = os.path.join(out_dir, "blobs")
    os.makedirs(blob_dir, exist_ok=True)
    key = jax.random.PRNGKey(seed)
    k_std, k_rev, k_peft = jax.random.split(key, 3)

    std = P.init_standard_model(k_std, cfg)
    rev = P.rev_model_from_standard(std, k_rev, cfg)
    blobs = {"standard": std, "revffn": rev}

    from .methods import init_dora, init_ia3, init_lora
    k1, k2 = jax.random.split(k_peft)
    blobs["peft_lora"] = {"lora": init_lora(k1, cfg, tc.lora_rank)}
    blobs["peft_dora"] = {"lora": init_lora(k2, cfg, tc.lora_rank),
                          "dora": init_dora(std, cfg)}
    blobs["peft_ia3"] = {"ia3": init_ia3(cfg)}

    index = {}
    for name, tree in blobs.items():
        path = os.path.join(blob_dir, f"{name}.bin")
        P.write_param_blob(tree, path)
        index[name] = _blob_index(tree)
    return blobs, index


def tensor_sources(sb: StepBuilder, method: str, blob_index: dict) -> list[dict]:
    """Map every flat tensor of the method's param tree to (blob, offset)."""
    out = []
    for path, shape in zip(sb.paths, sb.shapes):
        if method in ("revffn", "revffn_naive"):
            blob, key = "revffn", path
        elif path.startswith("base."):
            blob, key = "standard", path[len("base."):]
        elif path.startswith("peft."):
            blob, key = f"peft_{method}", path[len("peft."):]
        else:
            blob, key = "standard", path
        e = blob_index[blob][key]
        assert tuple(e["shape"]) == tuple(shape), (path, e["shape"], shape)
        out.append({"name": path, "shape": list(shape), "dtype": "f32",
                    "blob": blob, "offset": e["offset"], "nbytes": e["nbytes"]})
    return out


def lower_variant(variant: str, cfg: ModelConfig, tc: TrainConfig,
                  out_dir: str, blob_index: dict, use_pallas: bool,
                  analyze: bool) -> None:
    method = "revffn" if variant.startswith("revffn_stage") else variant
    vdir = os.path.join(out_dir, variant)
    os.makedirs(vdir, exist_ok=True)

    sb = StepBuilder(method, cfg, tc, use_pallas=use_pallas)
    p_spec, m_spec, v_spec, tok, tgt, msk, lr, step = sb.example_args()
    n_p, n_o = len(p_spec), len(m_spec)

    def flat_train(*args):
        params = list(args[:n_p])
        m = list(args[n_p:n_p + n_o])
        v = list(args[n_p + n_o:n_p + 2 * n_o])
        tokens, targets, mask, lr_, step_ = args[n_p + 2 * n_o:]
        new_p, new_m, new_v, loss, gnorm, aux = sb.train_step(
            params, m, v, tokens, targets, mask, lr_, step_)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss, gnorm, aux)

    # Donate params + optimizer state: XLA aliases these inputs to the
    # matching outputs, halving the live-buffer peak of the step (§Perf L2).
    donate = tuple(range(n_p + 2 * n_o))
    train_args = tuple(p_spec) + tuple(m_spec) + tuple(v_spec) + (tok, tgt, msk, lr, step)
    lowered_train = jax.jit(flat_train, donate_argnums=donate).lower(*train_args)
    _write(os.path.join(vdir, "train_step.hlo.txt"), to_hlo_text(lowered_train))

    # Microbatch-accumulation pair: grad-only pass + apply-accumulated pass
    # (the L3 scheduler sums grads across microbatches between the two).
    t_shapes = [sb.shapes[i] for i in sb.t_idx]
    g_spec = [jax.ShapeDtypeStruct(s, jnp.float32) for s in t_shapes]
    n_t = len(g_spec)

    def flat_grad(*args):
        grads, loss, aux = sb.grad_step(list(args[:n_p]), *args[n_p:])
        return tuple(grads) + (loss, aux)

    lowered_grad = jax.jit(flat_grad).lower(*(tuple(p_spec) + (tok, tgt, msk)))
    _write(os.path.join(vdir, "grad_step.hlo.txt"), to_hlo_text(lowered_grad))

    def flat_apply(*args):
        params = list(args[:n_p])
        m = list(args[n_p:n_p + n_o])
        v = list(args[n_p + n_o:n_p + 2 * n_o])
        grads = list(args[n_p + 2 * n_o:n_p + 2 * n_o + n_t])
        lr_, step_ = args[n_p + 2 * n_o + n_t:]
        new_p, new_m, new_v, gnorm = sb.apply_step(params, m, v, grads, lr_, step_)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (gnorm,)

    apply_args = (tuple(p_spec) + tuple(m_spec) + tuple(v_spec) + tuple(g_spec)
                  + (lr, step))
    lowered_apply = jax.jit(flat_apply, donate_argnums=donate).lower(*apply_args)
    _write(os.path.join(vdir, "apply_step.hlo.txt"), to_hlo_text(lowered_apply))

    # Device-resident accumulation pair: running sum + mean scale over the
    # trainable gradients. With these, L3's accumulate loop never moves a
    # gradient across the host boundary (runtime/accum.rs).
    donate_acc = tuple(range(n_t))

    def flat_accum(*args):
        acc = list(args[:n_t])
        grads = list(args[n_t:2 * n_t])
        return tuple(sb.accum_step(acc, grads))

    lowered_accum = jax.jit(flat_accum, donate_argnums=donate_acc).lower(
        *(tuple(g_spec) + tuple(g_spec)))
    _write(os.path.join(vdir, "accum_step.hlo.txt"), to_hlo_text(lowered_accum))

    def flat_scale(*args):
        acc = list(args[:n_t])
        return tuple(sb.scale_step(acc, args[n_t]))

    lowered_scale = jax.jit(flat_scale, donate_argnums=donate_acc).lower(
        *(tuple(g_spec) + (lr,)))
    _write(os.path.join(vdir, "scale.hlo.txt"), to_hlo_text(lowered_scale))

    def flat_forward(*args):
        return (sb.forward(list(args[:n_p]), args[n_p]),)

    lowered_fwd = jax.jit(flat_forward).lower(*(tuple(p_spec) + (tok,)))
    _write(os.path.join(vdir, "forward.hlo.txt"), to_hlo_text(lowered_fwd))

    def flat_eval(*args):
        loss, aux = sb.eval_step(list(args[:n_p]), *args[n_p:])
        return (loss, aux)

    lowered_eval = jax.jit(flat_eval).lower(*(tuple(p_spec) + (tok, tgt, msk)))
    _write(os.path.join(vdir, "eval_step.hlo.txt"), to_hlo_text(lowered_eval))

    manifest = {
        "variant": variant,
        "method": method,
        "model": cfg.to_json(),
        "train": tc.to_json(),
        "use_pallas": use_pallas,
        "io": sb.layout(),
        "tensors": tensor_sources(sb, method, blob_index),
        "artifacts": {
            "train_step": "train_step.hlo.txt",
            "grad_step": "grad_step.hlo.txt",
            "apply_step": "apply_step.hlo.txt",
            "accum_step": "accum_step.hlo.txt",
            "scale": "scale.hlo.txt",
            "forward": "forward.hlo.txt",
            "eval_step": "eval_step.hlo.txt",
        },
        "n_params_total": sum(int(np.prod(s)) for s in sb.shapes),
        "n_params_trainable": sum(
            int(np.prod(sb.shapes[i])) for i in sb.t_idx),
    }

    if analyze:
        def mem(jitted):
            ma = jitted.lower(*train_args).compile().memory_analysis()
            if ma is None:
                return None
            return {
                "temp_size_bytes": int(ma.temp_size_in_bytes),
                "argument_size_bytes": int(ma.argument_size_in_bytes),
                "output_size_bytes": int(ma.output_size_in_bytes),
                "generated_code_size_bytes": int(ma.generated_code_size_in_bytes),
            }

        # shipped (donated) step + the undonated variant for the §Perf
        # before/after record
        manifest["memory_analysis"] = mem(jax.jit(flat_train, donate_argnums=donate))
        manifest["memory_analysis_nodonate"] = mem(jax.jit(flat_train))

    _write(os.path.join(vdir, "manifest.json"), json.dumps(manifest, indent=2))
    print(f"  {variant}: {len(sb.paths)} tensors "
          f"({manifest['n_params_trainable']:,}/{manifest['n_params_total']:,} trainable), "
          f"opt={sb.spec.optimizer}")


def lower_reconstruct(cfg: ModelConfig, tc: TrainConfig, out_dir: str,
                      blob_index: dict, use_pallas: bool,
                      name: str = "reconstruct") -> None:
    """Reversibility round-trip error artifact (Fig-1/§3.1 claim, E5)."""
    vdir = os.path.join(out_dir, name)
    os.makedirs(vdir, exist_ok=True)
    sb = StepBuilder("revffn", cfg, tc, use_pallas=use_pallas)
    p_spec, _, _, tok, *_ = sb.example_args()
    n_p = len(p_spec)

    def flat_rec(*args):
        params = sb._assemble(list(args[:n_p]))
        err = revffn_reconstruct(params, args[n_p], cfg, use_pallas)
        # anchor all tensors (variants like rev_symmetric leave norm_x1
        # unused and jit would prune the argument)
        anchor = sum(jnp.sum(p) for p in args[:n_p])
        return (err + 0.0 * anchor,)

    lowered = jax.jit(flat_rec).lower(*(tuple(p_spec) + (tok,)))
    _write(os.path.join(vdir, "reconstruct.hlo.txt"), to_hlo_text(lowered))
    manifest = {
        "variant": name,
        "model": cfg.to_json(),
        "train": tc.to_json(),
        "io": sb.layout(),
        "tensors": tensor_sources(sb, "revffn", blob_index),
        "artifacts": {"reconstruct": "reconstruct.hlo.txt"},
    }
    _write(os.path.join(vdir, "manifest.json"), json.dumps(manifest, indent=2))
    print(f"  {name}: ok")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", default="tiny", choices=list(CONFIGS))
    ap.add_argument("--methods", default="all")
    ap.add_argument("--pallas", action="store_true",
                    help="route hot loops through the Pallas kernels")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--analyze", action="store_true",
                    help="embed XLA memory_analysis in manifests")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tag", default="",
                    help="suffix for the output dir (artifacts/<config><tag>)")
    args = ap.parse_args()

    cfg = CONFIGS[args.config]
    if args.methods == "all":
        variants = [m for m in METHODS if m != "revffn"]
        variants += ["revffn_stage1", "revffn_stage2", "revffn_naive"]
    else:
        variants = args.methods.split(",")

    out_dir = os.path.join(args.out, args.config + args.tag)
    os.makedirs(out_dir, exist_ok=True)
    base_tc = TrainConfig(batch_size=args.batch, seq_len=args.seq)

    print(f"[aot] config={args.config} out={out_dir} pallas={args.pallas}")
    _, blob_index = build_blobs(cfg, base_tc, out_dir, seed=args.seed)
    print(f"[aot] blobs written")

    for variant in variants:
        stage = 1 if variant == "revffn_stage1" else 2
        method = "revffn" if variant.startswith("revffn_stage") else variant
        tc = TrainConfig(method=method, batch_size=args.batch, seq_len=args.seq,
                         stage=stage)
        lower_variant(variant, cfg, tc, out_dir, blob_index, args.pallas,
                      args.analyze)

    lower_reconstruct(cfg, base_tc, out_dir, blob_index, args.pallas)
    # §3.1 analysis artifacts: fixed-point iteration sweep + the exactly-
    # invertible symmetric ablation (Reformer-style F(X2)). All share the
    # revffn blobs, so only the HLO differs.
    rec_variants = ["reconstruct"]
    if args.methods == "all":
        for iters in (2, 4):
            c = dataclasses.replace(cfg, rev_fixedpoint_iters=iters)
            nm = f"reconstruct_iters{iters}"
            lower_reconstruct(c, base_tc, out_dir, blob_index, args.pallas, name=nm)
            rec_variants.append(nm)
        c = dataclasses.replace(cfg, rev_symmetric=True)
        lower_reconstruct(c, base_tc, out_dir, blob_index, args.pallas,
                          name="reconstruct_symmetric")
        rec_variants.append("reconstruct_symmetric")

    top = {
        "config": args.config,
        "model": cfg.to_json(),
        "variants": variants + rec_variants,
        "blobs": {name: f"blobs/{name}.bin" for name in blob_index},
        "pallas": args.pallas,
    }
    _write(os.path.join(out_dir, "index.json"), json.dumps(top, indent=2))
    print(f"[aot] done: {len(variants)+1} variants")


if __name__ == "__main__":
    main()
