"""Parameter pytree construction, flattening, and manifest generation.

Parameters live in *nested dicts*; the AOT boundary flattens them to an
ordered list (sorted dotted paths) so the Rust coordinator can address
every tensor positionally. The manifest records name/shape/dtype/offset
plus per-method trainable flags, and is the single source of truth for
buffer layout on both sides of the boundary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig


# ---------------------------------------------------------------------------
# Flatten / unflatten with deterministic ordering
# ---------------------------------------------------------------------------

def flatten_params(params: dict) -> list[tuple[str, jax.Array]]:
    """Flatten a nested dict to sorted (dotted.path, leaf) pairs."""
    out: list[tuple[str, jax.Array]] = []

    def rec(prefix: str, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}.{k}" if prefix else k, node[k])
        else:
            out.append((prefix, node))

    rec("", params)
    return out


def unflatten_params(pairs: list[tuple[str, jax.Array]]) -> dict:
    root: dict = {}
    for path, leaf in pairs:
        keys = path.split(".")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = leaf
    return root


def param_paths(params: dict) -> list[str]:
    return [p for p, _ in flatten_params(params)]


def tree_like(paths_and_leaves: list[tuple[str, jax.Array]], values: list) -> dict:
    return unflatten_params(list(zip([p for p, _ in paths_and_leaves], values)))


# ---------------------------------------------------------------------------
# Initialisation
# ---------------------------------------------------------------------------

def _normal(key, shape, std=0.02, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * std


def init_attention(key, cfg: ModelConfig) -> dict:
    """Full-d_model 'pre-trained' attention block (Wq/Wk/Wv/Wo)."""
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    dkv = cfg.n_kv_heads * cfg.head_dim
    return {
        "wq": _normal(ks[0], (d, d)),
        "wk": _normal(ks[1], (d, dkv)),
        "wv": _normal(ks[2], (d, dkv)),
        "wo": _normal(ks[3], (d, d)),
    }


def init_moe(key, cfg: ModelConfig) -> dict:
    """Router + E SwiGLU experts + shared expert with sigmoid gate."""
    ks = jax.random.split(key, 8)
    d, e, f, fs = cfg.d_model, cfg.n_experts, cfg.d_ff_expert, cfg.d_ff_shared
    return {
        "router": _normal(ks[0], (d, e)),
        "wg": _normal(ks[1], (e, d, f)),
        "wu": _normal(ks[2], (e, d, f)),
        "wd": _normal(ks[3], (e, f, d)),
        "shared_wg": _normal(ks[4], (d, fs)),
        "shared_wu": _normal(ks[5], (d, fs)),
        "shared_wd": _normal(ks[6], (fs, d)),
        "shared_gate": _normal(ks[7], (d, 1)),
    }


def init_standard_layer(key, cfg: ModelConfig) -> dict:
    """One pre-norm decoder layer of the standard (baseline) transformer."""
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "attn": init_attention(k1, cfg),
        "moe": init_moe(k2, cfg),
        "norm_attn": jnp.ones((d,), jnp.float32),
        "norm_mlp": jnp.ones((d,), jnp.float32),
    }


def init_adapters(key, cfg: ModelConfig) -> dict:
    """RevFFN projection adapters P↑ (d/2→d) / P↓ (d→d/2) per sub-block.

    P↑ is initialised near a 'duplicate' map and P↓ near a 'halved-sum'
    map so that at t=0 the wrapped block approximates the pre-trained
    block seeing a duplicated half-stream — this keeps stage-1 warm-up
    short (§3.3) while remaining learnable.
    """
    ks = jax.random.split(key, 6)
    d, dh = cfg.d_model, cfg.d_half
    dup = jnp.concatenate([jnp.eye(dh), jnp.eye(dh)], axis=1)      # [dh, d]
    halve = jnp.concatenate([jnp.eye(dh), jnp.eye(dh)], axis=0) * 0.5  # [d, dh]
    return {
        "attn_up_q": dup + _normal(ks[0], (dh, d), 0.01),
        "attn_up_kv": dup + _normal(ks[1], (dh, d), 0.01),
        "attn_down": halve + _normal(ks[2], (d, dh), 0.01),
        "mlp_up": dup + _normal(ks[3], (dh, d), 0.01),
        "mlp_down": halve + _normal(ks[4], (d, dh), 0.01),
    }


def init_rev_layer(key, cfg: ModelConfig) -> dict:
    """One RevFFN reversible block: pre-trained attention+MoE wrapped with
    adapters; stream norms operate on d/2 features."""
    k1, k2, k3 = jax.random.split(key, 3)
    dh = cfg.d_half
    return {
        "attn": init_attention(k1, cfg),
        "moe": init_moe(k2, cfg),
        "adapters": init_adapters(k3, cfg),
        "norm_x1": jnp.ones((dh,), jnp.float32),
        "norm_x2": jnp.ones((dh,), jnp.float32),
        "norm_y1": jnp.ones((dh,), jnp.float32),
    }


def _stack_layers(layer_dicts: list[dict]) -> dict:
    """Stack per-layer param dicts along a leading axis for lax.scan."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layer_dicts)


def init_standard_model(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 2)
    layers = _stack_layers([init_standard_layer(ks[i], cfg) for i in range(cfg.n_layers)])
    return {
        "embed": _normal(ks[-2], (cfg.vocab_size, cfg.d_model)),
        "layers": layers,
        "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
    }


def init_rev_model(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 2)
    layers = _stack_layers([init_rev_layer(ks[i], cfg) for i in range(cfg.n_layers)])
    return {
        "embed": _normal(ks[-2], (cfg.vocab_size, cfg.d_model)),
        "layers": layers,
        "norm_f": jnp.ones((cfg.d_model,), jnp.float32),
    }


def rev_model_from_standard(std: dict, key, cfg: ModelConfig) -> dict:
    """Wrap a 'pre-trained' standard model into the RevFFN scaffold,
    re-using its attention/MoE/embedding weights (§3.2: plug-and-play)."""
    ks = jax.random.split(key, cfg.n_layers)
    dh = cfg.d_half
    adapters = _stack_layers([init_adapters(ks[i], cfg) for i in range(cfg.n_layers)])
    ones = jnp.ones((cfg.n_layers, dh), jnp.float32)
    return {
        "embed": std["embed"],
        "layers": {
            "attn": std["layers"]["attn"],
            "moe": std["layers"]["moe"],
            "adapters": adapters,
            "norm_x1": ones,
            "norm_x2": ones,
            "norm_y1": ones,
        },
        "norm_f": std["norm_f"],
    }


# ---------------------------------------------------------------------------
# Manifest / binary param blob
# ---------------------------------------------------------------------------

def manifest_entries(params: dict) -> list[dict]:
    """Per-tensor manifest rows (name, shape, dtype, byte offset/size)."""
    entries = []
    offset = 0
    for path, leaf in flatten_params(params):
        nbytes = int(np.prod(leaf.shape)) * 4  # f32 blob
        entries.append({
            "name": path,
            "shape": [int(s) for s in leaf.shape],
            "dtype": "f32",
            "offset": offset,
            "nbytes": nbytes,
        })
        offset += nbytes
    return entries


def write_param_blob(params: dict, path: str) -> int:
    """Concatenate all tensors (manifest order) as little-endian f32."""
    total = 0
    with open(path, "wb") as f:
        for _, leaf in flatten_params(params):
            arr = np.asarray(leaf, dtype=np.float32)
            f.write(arr.tobytes())
            total += arr.nbytes
    return total


def count_params(params: dict) -> int:
    return sum(int(np.prod(l.shape)) for _, l in flatten_params(params))
