"""Mixture-of-Experts block: top-k router + SwiGLU experts + shared expert.

Dense dispatch (see kernels/moe_ffn.py) keeps shapes static for AOT; the
router's load-balancing auxiliary loss is returned alongside the output so
the caller can add it to the objective (standard baselines) or log it
(RevFFN, whose routers stay frozen — §3.3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import diff
from .configs import ModelConfig
from .kernels import ref
from .layers import shared_expert


def moe_block(p: dict, x: jax.Array, cfg: ModelConfig, use_pallas: bool,
              adapters: dict | None = None, freeze_router: bool = False):
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = xt @ p["router"]
    if freeze_router:
        # §3.3: routing decisions are treated as constants — no gradient
        # flows into (or through) the gating network.
        logits = jax.lax.stop_gradient(logits)
    if use_pallas:
        combine, aux = diff.router_topk(logits, cfg.top_k)
    else:
        combine, aux = ref.router_topk(logits, cfg.top_k)
    if freeze_router:
        combine = jax.lax.stop_gradient(combine)
    if use_pallas:
        expert_out = diff.moe_ffn(xt, combine, p["wg"], p["wu"], p["wd"])
    else:
        expert_out = ref.moe_ffn(xt, combine, p["wg"], p["wu"], p["wd"])
    out = expert_out.reshape(b, s, d) + shared_expert(p, x, adapters)
    return out, aux
