"""Model / training configurations shared between the AOT compile path and
the Rust coordinator (via each artifact's manifest.json).

The geometry mirrors Qwen1.5-MoE-A2.7B structurally (RMSNorm, RoPE
attention, top-k router with renormalisation, SwiGLU experts plus a shared
expert) at a size that trains on this testbed.  ``qwen15_moe_a27b`` is the
real geometry used *analytically* by the Rust memory model for Table 1 —
it is never instantiated here.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    """Geometry of the MoE transformer backbone."""

    name: str = "tiny"
    vocab_size: int = 512
    d_model: int = 128          # must be even (reversible split) and % n_heads == 0
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 4         # GQA supported; tiny config uses MHA
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 176      # per-expert SwiGLU intermediate
    d_ff_shared: int = 352      # shared-expert intermediate
    max_seq_len: int = 128
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    # RevFFN specifics
    rev_fixedpoint_iters: int = 1   # paper §3.1: one iteration
    rev_symmetric: bool = False     # ablation: exactly-invertible F(X2) variant
    tie_embeddings: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_half(self) -> int:
        return self.d_model // 2

    def validate(self) -> None:
        assert self.d_model % 2 == 0, "reversible split needs even d_model"
        assert self.d_model % self.n_heads == 0
        assert self.n_heads % self.n_kv_heads == 0
        assert 1 <= self.top_k <= self.n_experts

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclass(frozen=True)
class TrainConfig:
    """Per-method training hyper-parameters baked into the train_step HLO."""

    method: str = "revffn"      # sft | lora | dora | ia3 | lomo | galore | revffn
    batch_size: int = 4
    seq_len: int = 64
    lr: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.999
    adam_eps: float = 1e-8
    grad_clip: float = 1.0
    # LoRA / DoRA
    lora_rank: int = 8
    lora_alpha: float = 16.0
    # GaLore
    galore_rank: int = 8
    galore_update_every: int = 50
    galore_scale: float = 0.25
    # RevFFN two-stage schedule
    stage: int = 2              # 1 = adapter warm-up, 2 = joint fine-tuning
    # aux loss weight for router load balancing
    router_aux_coef: float = 0.001
    label_smoothing: float = 0.0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Named geometries
# ---------------------------------------------------------------------------

TINY = ModelConfig()

SMALL = ModelConfig(
    name="small",
    vocab_size=2048,
    d_model=256,
    n_layers=6,
    n_heads=8,
    n_kv_heads=8,
    n_experts=8,
    top_k=2,
    d_ff_expert=352,
    d_ff_shared=704,
    max_seq_len=256,
)

# ~100M-parameter config for the long e2e run (CPU permitting).
MEDIUM = ModelConfig(
    name="medium",
    vocab_size=8192,
    d_model=512,
    n_layers=8,
    n_heads=8,
    n_kv_heads=8,
    n_experts=16,
    top_k=4,
    d_ff_expert=704,
    d_ff_shared=1408,
    max_seq_len=512,
)

# Real Qwen1.5-MoE-A2.7B geometry — analytic use only (Table 1 memory model).
QWEN15_MOE_A27B = ModelConfig(
    name="qwen15_moe_a27b",
    vocab_size=151936,
    d_model=2048,
    n_layers=24,
    n_heads=16,
    n_kv_heads=16,
    n_experts=60,
    top_k=4,
    d_ff_expert=1408,
    d_ff_shared=5632,
    max_seq_len=8192,
)

CONFIGS = {c.name: c for c in (TINY, SMALL, MEDIUM, QWEN15_MOE_A27B)}


def dump_config(model: ModelConfig, train: TrainConfig) -> str:
    return json.dumps({"model": model.to_json(), "train": train.to_json()}, indent=2)
