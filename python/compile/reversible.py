"""RevFFN reversible block and O(1)-activation backward pass (paper §3.1).

Forward coupling over a feature-split hidden state H = [X1, X2]:

    Y1 = X1 + F(X1, X2)      F = P↓(Attn_pt(P↑N(X1), P↑N(X2), P↑N(X2)))
    Y2 = X2 + G(Y1)          G = P↓(MoE_pt(P↑N(Y1)))

The map is a bijection; the inverse is

    X2 = Y2 − G(Y1)
    X1 = Y1 − F(X1, X2)      (fixed-point in X1: queries depend on X1;
                              seeded at X1⁽⁰⁾ = Y1, paper runs 1 iteration)

``rev_stack`` scans the blocks and carries a *custom VJP*: the forward
residuals are only the stack's **outputs** (plus parameters), and the
backward scan reconstructs each block's inputs from its outputs before
computing gradients. Peak live activations are therefore O(1) blocks
instead of O(L) — the paper's entire memory claim, visible in the lowered
HLO's live-buffer profile (rust memory calibration reads that profile).

``symmetric=True`` switches F to the exactly-invertible RevNet form
F(X2) (queries from the right stream) — the ablation variant the paper
credits to Reformer [17].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .layers import attention_block, norm, p_down, p_up
from .moe import moe_block


# ---------------------------------------------------------------------------
# F / G sub-functions (single block)
# ---------------------------------------------------------------------------

def rev_f(p: dict, x1: jax.Array, x2: jax.Array, cos, sin, cfg: ModelConfig,
          use_pallas: bool) -> jax.Array:
    """Cross-branch attention branch. Queries from X1 (or X2 when
    symmetric), keys/values from X2. Input/output: [B, S, d/2]."""
    a = p["adapters"]
    h2 = norm(x2, p["norm_x2"], cfg.rms_eps, use_pallas)
    if cfg.rev_symmetric:
        hq = h2
    else:
        hq = norm(x1, p["norm_x1"], cfg.rms_eps, use_pallas)
    q_in = p_up(hq, a["attn_up_q"])
    kv_in = p_up(h2, a["attn_up_kv"])
    attn = attention_block(p["attn"], q_in, kv_in, cos, sin, cfg, use_pallas)
    return p_down(attn, a["attn_down"])


def rev_g(p: dict, y1: jax.Array, cfg: ModelConfig, use_pallas: bool,
          freeze_router: bool = True):
    """MoE branch driven by the updated left stream. [B,S,d/2] -> same."""
    a = p["adapters"]
    h = norm(y1, p["norm_y1"], cfg.rms_eps, use_pallas)
    x_in = p_up(h, a["mlp_up"])
    moe_out, aux = moe_block(p["moe"], x_in, cfg, use_pallas,
                             freeze_router=freeze_router)
    return p_down(moe_out, a["mlp_down"]), aux


def rev_block_forward(p: dict, x1, x2, cos, sin, cfg: ModelConfig, use_pallas: bool):
    """One coupled update. Returns (y1, y2, aux)."""
    y1 = x1 + rev_f(p, x1, x2, cos, sin, cfg, use_pallas)
    g_out, aux = rev_g(p, y1, cfg, use_pallas)
    y2 = x2 + g_out
    return y1, y2, aux


def rev_block_inverse(p: dict, y1, y2, cos, sin, cfg: ModelConfig, use_pallas: bool):
    """Exact-inverse reconstruction (§3.1). Returns (x1, x2)."""
    g_out, _ = rev_g(p, y1, cfg, use_pallas)
    x2 = y2 - g_out
    if cfg.rev_symmetric:
        # F depends only on x2 — closed-form inverse.
        return y1 - rev_f(p, y1, x2, cos, sin, cfg, use_pallas), x2
    x1 = y1  # X1⁽⁰⁾ = Y1 seed
    for _ in range(max(1, cfg.rev_fixedpoint_iters)):
        x1 = y1 - rev_f(p, x1, x2, cos, sin, cfg, use_pallas)
    return x1, x2


# ---------------------------------------------------------------------------
# Reversible stack with O(1)-activation custom VJP
# ---------------------------------------------------------------------------

def make_rev_stack(cfg: ModelConfig, use_pallas: bool):
    """Return rev_stack(stacked_params, x1, x2, cos, sin) -> (y1, y2, aux).

    stacked_params: per-layer dicts stacked on axis 0 (lax.scan layout).
    aux is the summed router load-balance statistic (stop-gradiented: the
    RevFFN schedule freezes routers, so it is a metric, not an objective).
    """

    def fwd_scan(sp, x1, x2, cos, sin):
        def step(carry, p):
            c1, c2, aux = carry
            y1, y2, a = rev_block_forward(p, c1, c2, cos, sin, cfg, use_pallas)
            return (y1, y2, aux + jax.lax.stop_gradient(a)), None

        (y1, y2, aux), _ = jax.lax.scan(step, (x1, x2, jnp.float32(0.0)), sp)
        return y1, y2, aux

    @jax.custom_vjp
    def rev_stack(sp, x1, x2, cos, sin):
        return fwd_scan(sp, x1, x2, cos, sin)

    def rev_stack_fwd(sp, x1, x2, cos, sin):
        y1, y2, aux = fwd_scan(sp, x1, x2, cos, sin)
        # Residuals: outputs + params only. NO per-layer activations.
        return (y1, y2, aux), (sp, y1, y2, cos, sin)

    def rev_stack_bwd(res, cotangents):
        sp, y1, y2, cos, sin = res
        gy1, gy2, _gaux = cotangents

        def block_fwd_for_vjp(p, a, b):
            o1, o2, _ = rev_block_forward(p, a, b, cos, sin, cfg, use_pallas)
            return o1, o2

        def step(carry, p):
            cy1, cy2, cg1, cg2 = carry
            x1, x2 = rev_block_inverse(p, cy1, cy2, cos, sin, cfg, use_pallas)
            x1 = jax.lax.stop_gradient(x1)
            x2 = jax.lax.stop_gradient(x2)
            _, vjp = jax.vjp(block_fwd_for_vjp, p, x1, x2)
            gp, gx1, gx2 = vjp((cg1, cg2))
            return (x1, x2, gx1, gx2), gp

        (x1, x2, gx1, gx2), gps = jax.lax.scan(
            step, (y1, y2, gy1, gy2), sp, reverse=True
        )
        zc = jnp.zeros_like(cos)
        zs = jnp.zeros_like(sin)
        return gps, gx1, gx2, zc, zs

    rev_stack.defvjp(rev_stack_fwd, rev_stack_bwd)
    return rev_stack


def make_rev_stack_naive(cfg: ModelConfig, use_pallas: bool):
    """Same forward WITHOUT the custom VJP — autodiff caches every layer's
    activations. Used by tests (gradient equivalence) and by the memory
    calibration as the 'non-reversible' upper bound."""

    def rev_stack(sp, x1, x2, cos, sin):
        def step(carry, p):
            c1, c2, aux = carry
            y1, y2, a = rev_block_forward(p, c1, c2, cos, sin, cfg, use_pallas)
            return (y1, y2, aux + jax.lax.stop_gradient(a)), None

        (y1, y2, aux), _ = jax.lax.scan(step, (x1, x2, jnp.float32(0.0)), sp)
        return y1, y2, aux

    return rev_stack
