"""Assemble per-method train/eval step functions with a *flat* tensor
boundary, ready for AOT lowering.

Step signature (what the Rust coordinator executes every iteration):

    inputs : [params_0 … params_{P-1},          # ALL model tensors
              m_0 … m_{T-1}, v_0 … v_{T-1},     # moments (trainable only;
                                                #  absent for sgd/lomo)
              tokens  i32[B,S],
              targets i32[B,S],
              loss_mask f32[B,S],
              lr f32[], step f32[]]
    outputs: [new_params…, new_m…, new_v…, loss, grad_norm, aux]

Frozen tensors pass through unchanged (XLA turns them into aliased
no-ops); gradients are only computed for trainable tensors, which is what
gives PEFT/RevFFN their optimizer-state savings in the manifest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import optim
from .configs import ModelConfig, TrainConfig
from .methods import MethodSpec, decay_mask, get_method
from .model import make_loss_fn
from .params import flatten_params, unflatten_params


class StepBuilder:
    """Builds the flat-boundary step functions + layout metadata for one
    (method, model config, train config) triple."""

    def __init__(self, method: str, cfg: ModelConfig, tc: TrainConfig,
                 use_pallas: bool = False, seed: int = 0):
        self.cfg = cfg
        self.tc = tc
        self.spec: MethodSpec = get_method(method, cfg, tc, use_pallas)
        key = jax.random.PRNGKey(seed)
        self.params = self.spec.init(key)
        pairs = flatten_params(self.params)
        self.paths = [p for p, _ in pairs]
        self.shapes = [tuple(l.shape) for _, l in pairs]
        self.dtypes = [l.dtype for _, l in pairs]
        self.trainable = [self.spec.trainable(p) for p in self.paths]
        self.t_idx = [i for i, t in enumerate(self.trainable) if t]
        t_paths = [self.paths[i] for i in self.t_idx]
        t_shapes = [self.shapes[i] for i in self.t_idx]
        self.decay = decay_mask(t_paths, t_shapes)
        self.loss_fn = make_loss_fn(self.spec.forward, tc, self.spec.router_aux)

        if self.spec.optimizer == "sgd":
            self.opt_shapes: list[tuple] = []
        elif self.spec.optimizer == "galore":
            t_params = [pairs[i][1] for i in self.t_idx]
            self.opt_shapes = optim.galore_shapes(t_params, t_paths, tc.galore_rank)
        else:
            self.opt_shapes = t_shapes

    # -- pytree plumbing ----------------------------------------------------

    def _assemble(self, flat_list: list) -> dict:
        return unflatten_params(list(zip(self.paths, flat_list)))

    # -- step functions -----------------------------------------------------

    def train_step(self, all_params: list, m: list, v: list, tokens, targets,
                   loss_mask, lr, step):
        """Pure function — the body of the train_step HLO artifact."""
        tc = self.tc

        def loss_of_trainable(trainable_list):
            full = list(all_params)
            for i, idx in enumerate(self.t_idx):
                full[idx] = trainable_list[i]
            return self.loss_fn(self._assemble(full), tokens, targets, loss_mask)

        t_params = [all_params[i] for i in self.t_idx]
        (loss, aux), grads = jax.value_and_grad(loss_of_trainable, has_aux=True)(t_params)
        grads, gnorm = optim.clip_by_global_norm(grads, tc.grad_clip)

        if self.spec.optimizer == "sgd":
            new_t = optim.sgd_update(t_params, grads, lr, tc)
            new_m, new_v = [], []
        elif self.spec.optimizer == "galore":
            new_t, new_m, new_v = optim.galore_update(
                t_params, grads, m, v, lr, step, tc, self.decay)
        else:
            new_t, new_m, new_v = optim.adamw_update(
                t_params, grads, m, v, lr, step, tc, self.decay)

        new_all = list(all_params)
        for i, idx in enumerate(self.t_idx):
            new_all[idx] = new_t[i]
        # anchor every scalar input into the graph so jax.jit never prunes
        # arguments (the Rust caller always supplies the full manifest list;
        # e.g. plain SGD has no bias correction and would drop `step`).
        loss = loss + 0.0 * lr + 0.0 * step
        return new_all, new_m, new_v, loss, gnorm, aux

    def grad_step(self, all_params: list, tokens, targets, loss_mask):
        """Gradient-only pass for microbatch accumulation (L3 sums the
        returned trainable grads host-side across microbatches):
        -> (grads_trainable…, loss, aux). No clipping — that happens in
        apply_step on the *accumulated* gradient."""

        def loss_of_trainable(trainable_list):
            full = list(all_params)
            for i, idx in enumerate(self.t_idx):
                full[idx] = trainable_list[i]
            return self.loss_fn(self._assemble(full), tokens, targets, loss_mask)

        t_params = [all_params[i] for i in self.t_idx]
        (loss, aux), grads = jax.value_and_grad(loss_of_trainable, has_aux=True)(t_params)
        return grads, loss, aux

    def apply_step(self, all_params: list, m: list, v: list, grads: list, lr, step):
        """Apply one accumulated gradient: clip + optimizer update.
        -> (new_params…, new_m…, new_v…, grad_norm)."""
        tc = self.tc
        grads, gnorm = optim.clip_by_global_norm(list(grads), tc.grad_clip)
        t_params = [all_params[i] for i in self.t_idx]
        if self.spec.optimizer == "sgd":
            new_t = optim.sgd_update(t_params, grads, lr, tc)
            new_m, new_v = [], []
        elif self.spec.optimizer == "galore":
            new_t, new_m, new_v = optim.galore_update(
                t_params, grads, m, v, lr, step, tc, self.decay)
        else:
            new_t, new_m, new_v = optim.adamw_update(
                t_params, grads, m, v, lr, step, tc, self.decay)
        new_all = list(all_params)
        for i, idx in enumerate(self.t_idx):
            new_all[idx] = new_t[i]
        gnorm = gnorm + 0.0 * lr + 0.0 * step  # anchor scalar inputs
        return new_all, new_m, new_v, gnorm

    def accum_step(self, acc: list, grads: list):
        """Running-sum for device-resident microbatch accumulation:
        -> (acc + g per trainable tensor). L3 keeps the sum as XLA
        literals across microbatches; donation aliases `acc` in place."""
        return [a + g for a, g in zip(acc, grads)]

    def scale_step(self, acc: list, scale):
        """Scale the accumulated gradient (by 1/n_microbatches) into the
        mean the apply_step consumes: -> acc * scale."""
        return [a * scale for a in acc]

    def eval_step(self, all_params: list, tokens, targets, loss_mask):
        """Loss-only pass (validation): -> (loss, aux)."""
        return self.loss_fn(self._assemble(all_params), tokens, targets, loss_mask)

    def forward(self, all_params: list, tokens):
        """Logits pass (the eval suite's scoring primitive)."""
        logits, aux = self.spec.forward(self._assemble(all_params), tokens)
        return logits

    # -- example args for lowering -------------------------------------------

    def example_args(self):
        b, s = self.tc.batch_size, self.tc.seq_len
        params = [jax.ShapeDtypeStruct(sh, dt) for sh, dt in zip(self.shapes, self.dtypes)]
        m = [jax.ShapeDtypeStruct(sh, jnp.float32) for sh in self.opt_shapes]
        v = [jax.ShapeDtypeStruct(sh, jnp.float32) for sh in self.opt_shapes]
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
        targets = jax.ShapeDtypeStruct((b, s), jnp.int32)
        mask = jax.ShapeDtypeStruct((b, s), jnp.float32)
        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        return params, m, v, tokens, targets, mask, scalar, scalar

    def layout(self) -> dict:
        """Manifest 'io' section: how Rust must order buffers."""
        return {
            "n_params": len(self.paths),
            "n_opt": len(self.opt_shapes),
            "optimizer": self.spec.optimizer,
            "trainable": self.trainable,
            "trainable_paths": [self.paths[i] for i in self.t_idx],
            "opt_shapes": [list(s) for s in self.opt_shapes],
            "batch_size": self.tc.batch_size,
            "seq_len": self.tc.seq_len,
            "train_inputs": "params*, m*, v*, tokens, targets, loss_mask, lr, step",
            "train_outputs": "params*, m*, v*, loss, grad_norm, aux",
        }
