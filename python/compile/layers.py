"""L2 building blocks: norm dispatch, 'pre-trained' full-d attention,
SwiGLU, and the RevFFN projection adapters.

Every block takes ``use_pallas``: True routes the hot loops through the L1
Pallas kernels (interpret=True) so they lower into the same HLO; False
uses the pure-jnp oracles. Both paths are numerically equivalent (enforced
by python/tests/test_model.py) — the artifact builder chooses per target.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import diff
from .configs import ModelConfig
from .kernels import ref


def norm(x: jax.Array, gamma: jax.Array, eps: float, use_pallas: bool) -> jax.Array:
    if use_pallas:
        return diff.rmsnorm(x, gamma, eps)
    return ref.rmsnorm(x, gamma, eps)


def p_up(x: jax.Array, w: jax.Array) -> jax.Array:
    """Projection adapter P↑: [..., d/2] @ [d/2, d] -> [..., d] (§3.2)."""
    return x @ w


def p_down(x: jax.Array, w: jax.Array) -> jax.Array:
    """Projection adapter P↓: [..., d] @ [d, d/2] -> [..., d/2] (§3.2)."""
    return x @ w


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1).transpose(0, 2, 1, 3)  # [B,H,S,hd]


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def attention_block(p: dict, q_input: jax.Array, kv_input: jax.Array,
                    cos: jax.Array, sin: jax.Array, cfg: ModelConfig,
                    use_pallas: bool, adapters: dict | None = None) -> jax.Array:
    """'Pre-trained' attention operating at full d_model.

    q_input/kv_input: [B, S, d]. For the standard transformer the two are
    the same tensor (self-attention); for RevFFN queries come from the left
    stream and keys/values from the right (§3.1). RoPE on Q/K; causal mask.

    ``adapters`` optionally carries PEFT state:
      {"lora": {wq_a, wq_b, ...}, "dora": {mq, ...}, "ia3": {lk, lv}}
    applied inside so every baseline shares this one code path.
    """
    def proj(x, w, name):
        if adapters and "dora" in adapters and f"m_{name}" in adapters["dora"]:
            # DoRA: W' = m ⊙ (W + ΔW)/||W + ΔW||_col  (ΔW = scale·A@B)
            la, lb = adapters["lora"][f"{name}_a"], adapters["lora"][f"{name}_b"]
            w_eff = w + (la @ lb) * adapters["lora_scale"]
            col_norm = jnp.linalg.norm(w_eff, axis=0, keepdims=True)
            m = adapters["dora"][f"m_{name}"]
            return (x @ w_eff) * (m / col_norm)
        y = x @ w
        if adapters and "lora" in adapters and f"{name}_a" in adapters["lora"]:
            la, lb = adapters["lora"][f"{name}_a"], adapters["lora"][f"{name}_b"]
            y = y + (x @ la) @ lb * adapters["lora_scale"]
        return y

    q = proj(q_input, p["wq"], "wq")
    k = proj(kv_input, p["wk"], "wk")
    v = proj(kv_input, p["wv"], "wv")
    if adapters and "ia3" in adapters:
        k = k * adapters["ia3"]["lk"]
        v = v * adapters["ia3"]["lv"]

    q = _split_heads(q, cfg.n_heads)
    k = _split_heads(k, cfg.n_kv_heads)
    v = _split_heads(v, cfg.n_kv_heads)
    q = ref.apply_rope(q, cos, sin)
    k = ref.apply_rope(k, cos, sin)
    if use_pallas:
        o = diff.attention(q, k, v, causal=True)
    else:
        o = ref.attention(q, k, v, causal=True)
    return proj(_merge_heads(o), p["wo"], "wo")


def shared_expert(p: dict, x: jax.Array, adapters: dict | None = None) -> jax.Array:
    """Qwen-style always-on shared expert with sigmoid output gate."""
    h = jax.nn.silu(x @ p["shared_wg"]) * (x @ p["shared_wu"])
    if adapters and "ia3" in adapters:
        h = h * adapters["ia3"]["lff"]
    out = h @ p["shared_wd"]
    gate = jax.nn.sigmoid(x @ p["shared_gate"])
    return out * gate
