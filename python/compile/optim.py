"""Optimizers, implemented functionally over flat (path, tensor) lists.

Three families, matching the paper's baselines:

* ``adamw``      — AdamW with decoupled weight decay (SFT, PEFT, RevFFN).
* ``sgd_fused``  — stateless SGD, the LoMo [22] memory profile: no m/v
                   buffers; the fused gradient→update pass is a property
                   of the *memory model* (rust/src/memory), the math here
                   is plain SGD with gradient clipping.
* ``galore_adamw`` — GaLore [23]: gradients of 2-D tensors are projected
                   into a rank-r subspace (seeded Gaussian projection,
                   refreshed every ``update_every`` steps inside the
                   graph via fold_in(step // T)), AdamW moments live in
                   the subspace, updates are projected back.

Every update takes and returns flat tensor lists so the lowered HLO's
input/output layout matches the Rust manifest exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import TrainConfig


def global_norm(grads: list[jax.Array]) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in grads))


def clip_by_global_norm(grads: list[jax.Array], max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-6))
    return [g * scale for g in grads], gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_update(params: list, grads: list, m: list, v: list, lr, step,
                 tc: TrainConfig, decay_mask: list[bool]):
    """One AdamW step. ``step`` is 1-based (bias correction). Returns
    (new_params, new_m, new_v)."""
    b1, b2, eps = tc.beta1, tc.beta2, tc.adam_eps
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi, dm in zip(params, grads, m, v, decay_mask):
        g32 = g.astype(jnp.float32)
        mn = b1 * mi + (1.0 - b1) * g32
        vn = b2 * vi + (1.0 - b2) * jnp.square(g32)
        update = (mn / bc1) / (jnp.sqrt(vn / bc2) + eps)
        if dm:
            update = update + tc.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * update).astype(p.dtype))
        new_m.append(mn)
        new_v.append(vn)
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# LoMo-style stateless SGD
# ---------------------------------------------------------------------------

def sgd_update(params: list, grads: list, lr, tc: TrainConfig):
    new_p = []
    for p, g in zip(params, grads):
        new_p.append((p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype))
    return new_p


# ---------------------------------------------------------------------------
# GaLore
# ---------------------------------------------------------------------------

def _galore_proj(shape: tuple, rank: int, step, base_seed: int, update_every: int):
    """Deterministic Gaussian projection P [r, min_dim], refreshed every
    ``update_every`` steps (seed folds in step // T)."""
    epoch = (step // update_every).astype(jnp.int32)
    key = jax.random.fold_in(jax.random.PRNGKey(base_seed), epoch)
    min_dim = min(shape)
    p = jax.random.normal(key, (rank, min_dim), jnp.float32)
    return p / jnp.sqrt(jnp.float32(rank))


def galore_shapes(params: list, paths: list[str], rank: int):
    """Moment shapes for each tensor: 2-D tensors get rank-r subspace
    moments [r, other_dim]; others get full-shape moments."""
    shapes = []
    for p in params:
        if p.ndim == 2 and min(p.shape) > rank:
            other = p.shape[1] if p.shape[0] <= p.shape[1] else p.shape[0]
            shapes.append((rank, other))
        else:
            shapes.append(tuple(p.shape))
    return shapes


def galore_update(params: list, grads: list, m: list, v: list, lr, step,
                  tc: TrainConfig, decay_mask: list[bool], base_seed: int = 1234):
    """GaLore-AdamW. 2-D tensors: moments in the projected space; the
    de-projected update is scaled by ``galore_scale``. Others: plain AdamW."""
    b1, b2, eps = tc.beta1, tc.beta2, tc.adam_eps
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    new_p, new_m, new_v = [], [], []
    for i, (p, g, mi, vi, dm) in enumerate(zip(params, grads, m, v, decay_mask)):
        g32 = g.astype(jnp.float32)
        if p.ndim == 2 and min(p.shape) > tc.galore_rank:
            proj = _galore_proj(p.shape, tc.galore_rank, step, base_seed + i,
                                tc.galore_update_every)
            lead = p.shape[0] <= p.shape[1]
            r = proj @ g32 if lead else proj @ g32.T      # [r, other]
            mn = b1 * mi + (1.0 - b1) * r
            vn = b2 * vi + (1.0 - b2) * jnp.square(r)
            upd_r = (mn / bc1) / (jnp.sqrt(vn / bc2) + eps)
            upd = proj.T @ upd_r if lead else (proj.T @ upd_r).T
            upd = tc.galore_scale * upd
        else:
            mn = b1 * mi + (1.0 - b1) * g32
            vn = b2 * vi + (1.0 - b2) * jnp.square(g32)
            upd = (mn / bc1) / (jnp.sqrt(vn / bc2) + eps)
        if dm:
            upd = upd + tc.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(mn)
        new_v.append(vn)
    return new_p, new_m, new_v
