"""Pallas reversible-coupling kernels.

The stream updates of the RevFFN bijection (§3.1) are elementwise adds and
subtracts over (B, S, d/2) tensors; fusing them into single kernels keeps
the coupled update one HBM round-trip per stream on real hardware. Trivial
compute, but they pin down the coupling's numerics: the *same* kernel is
used on the forward and inverse paths, so reconstruction cancels exactly
in floating point (x + f - f == x bitwise for these elementwise ops).
``interpret=True`` always.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _sub_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] - b_ref[...]


def _couple(a: jax.Array, b: jax.Array, kernel, block_rows: int = 256) -> jax.Array:
    orig_shape = a.shape
    d = orig_shape[-1]
    rows = a.size // d
    a2 = a.reshape(rows, d)
    b2 = b.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        a2 = jnp.pad(a2, ((0, pad), (0, 0)))
        b2 = jnp.pad(b2, ((0, pad), (0, 0)))
    grid = (a2.shape[0] // br,)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(a2.shape, a.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        interpret=True,
    )(a2, b2)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)


def couple_add(x: jax.Array, fx: jax.Array) -> jax.Array:
    """y = x + f(x') — the forward coupling update."""
    return _couple(x, fx, _add_kernel)


def couple_sub(y: jax.Array, fx: jax.Array) -> jax.Array:
    """x = y - f(x') — the inverse coupling update."""
    return _couple(y, fx, _sub_kernel)
