"""Differentiable wrappers for the Pallas kernels.

``pallas_call`` carries no reverse-mode autodiff rule (interpret mode
included), so each kernel is wrapped in ``jax.custom_vjp``: the primal
runs the Pallas kernel (and therefore appears in the lowered HLO), the
backward pass is the VJP of the pure-jnp oracle — mathematically the same
function (enforced by test_kernels.py), so gradients are exact up to the
kernels' float tolerance. This mirrors production practice where a hand-
written kernel ships with a hand-written (or reference-derived) backward.
"""

from __future__ import annotations

import jax

from . import ref
from .attention import attention as _attention_kernel
from .moe_ffn import moe_ffn as _moe_ffn_kernel
from .rmsnorm import rmsnorm as _rmsnorm_kernel
from .router import router_topk as _router_kernel


def _with_ref_vjp(kernel_fn, ref_fn):
    @jax.custom_vjp
    def f(*args):
        return kernel_fn(*args)

    def fwd(*args):
        return f(*args), args

    def bwd(args, g):
        _, vjp = jax.vjp(ref_fn, *args)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


# rmsnorm(x, gamma, eps): eps is a non-diff scalar — close over defaults and
# expose (x, gamma) as diff args.
def rmsnorm(x, gamma, eps: float = 1e-6):
    wrapped = _with_ref_vjp(
        lambda a, b: _rmsnorm_kernel(a, b, eps),
        lambda a, b: ref.rmsnorm(a, b, eps),
    )
    return wrapped(x, gamma)


def attention(q, k, v, causal: bool = True):
    wrapped = _with_ref_vjp(
        lambda a, b, c: _attention_kernel(a, b, c, causal=causal),
        lambda a, b, c: ref.attention(a, b, c, causal=causal),
    )
    return wrapped(q, k, v)


def router_topk(logits, top_k: int, renormalize: bool = True):
    wrapped = _with_ref_vjp(
        lambda l: _router_kernel(l, top_k, renormalize),
        lambda l: ref.router_topk(l, top_k, renormalize),
    )
    return wrapped(logits)


def moe_ffn(x, combine, w_gate, w_up, w_down):
    wrapped = _with_ref_vjp(_moe_ffn_kernel, ref.moe_ffn)
    return wrapped(x, combine, w_gate, w_up, w_down)
