"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal: each kernel in this package must
match its oracle to float32 tolerance under pytest (see
python/tests/test_kernels.py, which hypothesis-sweeps shapes and dtypes).
The L2 model can also be built entirely from these references
(``use_pallas=False``) — both paths lower to HLO and must agree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMS layer norm over the last axis: x * gamma / rms(x)."""
    orig_dtype = x.dtype
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)
    return out.astype(orig_dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_angles(seq_len: int, head_dim: int, theta: float = 10000.0):
    """Return (cos, sin) each of shape [seq_len, head_dim/2]."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv_freq)  # [S, hd/2]
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., S, head_dim] with head_dim even; rotate-half convention
    (first half paired with second half, as in Llama/Qwen)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Scaled-dot-product causal attention (cross-branch: Q from left stream,
# K/V from right stream — shapes identical to self-attention)
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True) -> jax.Array:
    """q,k,v: [B, H, S, hd] (K/V may have fewer heads — GQA — with H % Hkv == 0).

    Returns [B, H, S, hd]. Softmax in float32.
    """
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# MoE router: softmax over expert logits, top-k selection, renormalised
# weights scattered back to a dense [T, E] combine matrix.
# ---------------------------------------------------------------------------

def router_topk(logits: jax.Array, top_k: int, renormalize: bool = True):
    """logits: [T, E]. Returns (combine [T, E] float32, aux_loss scalar).

    combine[t, e] = renormalised softmax prob if e in top-k(t) else 0.
    aux_loss is the Switch-style load-balancing loss: E/k * sum_e f_e * p_e,
    with f_e the fraction of token-slots routed to e and p_e the mean router
    probability.
    """
    t, e = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    # k-round argmax extraction instead of lax.top_k: identical result for
    # distinct probabilities, matches the Pallas kernel's loop exactly, and
    # avoids the TopK HLO op (whose `largest` attribute the pinned
    # xla_extension 0.5.1 text parser rejects).
    remaining = probs
    mask_total = jnp.zeros_like(probs)
    picked_sum = jnp.zeros((t, 1), jnp.float32)
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)
        mask_total = mask_total + onehot
        picked_sum = picked_sum + jnp.sum(onehot * probs, axis=-1, keepdims=True)
        remaining = remaining * (1.0 - onehot)
    combine = probs * mask_total
    if renormalize:
        combine = combine / picked_sum
    mask = (combine > 0).astype(jnp.float32)
    frac_tokens = jnp.mean(mask, axis=0)          # [E]
    mean_prob = jnp.mean(probs, axis=0)           # [E]
    aux = e * jnp.sum(frac_tokens * mean_prob) / top_k
    return combine, aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# SwiGLU expert FFN, dense-dispatch MoE combine
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """x: [T, d]; w_gate/w_up: [d, f]; w_down: [f, d]."""
    g = jax.nn.silu(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def moe_ffn(x: jax.Array, combine: jax.Array, w_gate: jax.Array, w_up: jax.Array,
            w_down: jax.Array) -> jax.Array:
    """Dense-dispatch mixture of SwiGLU experts.

    x: [T, d]; combine: [T, E] (zeros off the top-k);
    w_gate/w_up: [E, d, f]; w_down: [E, f, d]. Returns [T, d].

    Dense dispatch (every expert sees every token, masked by ``combine``)
    keeps the computation differentiable and shape-static; the Pallas kernel
    mirrors this contraction pattern with expert-tiled blocks.
    """
    x32 = x.astype(jnp.float32)
    g = jnp.einsum("td,edf->etf", x32, w_gate.astype(jnp.float32))
    u = jnp.einsum("td,edf->etf", x32, w_up.astype(jnp.float32))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("etf,efd->etd", h, w_down.astype(jnp.float32))
    out = jnp.einsum("te,etd->td", combine.astype(jnp.float32), y)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Reversible coupling (the RevFFN bijection, stream-level)
# ---------------------------------------------------------------------------

def couple_forward(x1: jax.Array, x2: jax.Array, f_fn, g_fn) -> tuple:
    """y1 = x1 + f(x1, x2) ; y2 = x2 + g(y1). Returns (y1, y2)."""
    y1 = x1 + f_fn(x1, x2)
    y2 = x2 + g_fn(y1)
    return y1, y2


def couple_inverse(y1: jax.Array, y2: jax.Array, f_fn, g_fn, n_iters: int = 1):
    """Invert the coupling: x2 = y2 - g(y1); x1 by fixed-point iteration
    x1 <- y1 - f(x1, x2), seeded with x1^(0) = y1 (paper §3.1)."""
    x2 = y2 - g_fn(y1)
    x1 = y1
    for _ in range(max(1, n_iters)):
        x1 = y1 - f_fn(x1, x2)
    return x1, x2
