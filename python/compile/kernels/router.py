"""Pallas top-k MoE router kernel.

Computes softmax over expert logits and extracts the top-k experts per
token by iterative max-extraction (k passes over the E axis — E is small,
so this beats a full sort and vectorises cleanly over the token tile).
Produces the dense [T, E] combine matrix the moe_ffn kernel consumes.

The load-balancing auxiliary loss needs global (all-token) statistics, so
it stays at the jnp level in the caller (see model.moe_block); the kernel
is the per-token hot loop. ``interpret=True`` always.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _router_kernel(logits_ref, comb_ref, *, top_k: int, renormalize: bool):
    logits = logits_ref[...].astype(jnp.float32)          # [bt, E]
    bt, e = logits.shape
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    probs = p / jnp.sum(p, axis=-1, keepdims=True)

    remaining = probs
    mask_total = jnp.zeros_like(probs)
    picked_sum = jnp.zeros((bt, 1), jnp.float32)
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)              # [bt]
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)
        mask_total = mask_total + onehot
        picked_sum = picked_sum + jnp.sum(onehot * probs, axis=-1, keepdims=True)
        remaining = remaining * (1.0 - onehot)
    combine = probs * mask_total
    if renormalize:
        combine = combine / picked_sum
    comb_ref[...] = combine.astype(comb_ref.dtype)


def router_topk(logits: jax.Array, top_k: int, renormalize: bool = True,
                block_t: int = 256):
    """logits: [T, E]. Returns (combine [T, E] float32, aux_loss scalar).

    Matches ref.router_topk (combine via kernel; aux loss computed at the
    jnp level from the kernel's combine output — identical formula)."""
    t, e = logits.shape
    bt = min(block_t, t)
    pad = (-t) % bt
    lp = jnp.pad(logits, ((0, pad), (0, 0))) if pad else logits
    grid = (lp.shape[0] // bt,)
    combine = pl.pallas_call(
        functools.partial(_router_kernel, top_k=top_k, renormalize=renormalize),
        out_shape=jax.ShapeDtypeStruct(lp.shape, jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, e), lambda i: (i, 0)),
        interpret=True,
    )(lp)
    if pad:
        combine = combine[:t]
    # aux loss from global statistics (same formula as ref.router_topk)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    mask = (combine > 0).astype(jnp.float32)
    aux = e * jnp.sum(jnp.mean(mask, axis=0) * jnp.mean(probs, axis=0)) / top_k
    return combine, aux.astype(jnp.float32)
