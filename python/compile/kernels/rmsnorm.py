"""Pallas RMSNorm kernel.

Tiles the token dimension; each grid step normalises a (block_rows, d)
tile held in VMEM-style scratch. ``interpret=True`` always (CPU PJRT); on a
real TPU the same BlockSpec maps tiles into VMEM with the feature axis
padded to the 128-lane register width.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, gamma_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * gamma_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
            block_rows: int = 128) -> jax.Array:
    """x: [..., d]; gamma: [d]. Matches ref.rmsnorm."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    # pad rows to a multiple of the block
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        interpret=True,
    )(x2, gamma)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
