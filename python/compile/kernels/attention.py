"""Pallas flash-style causal attention kernel (cross-branch capable).

RevFFN's attention takes queries from the *left* reversible stream and
keys/values from the *right* stream (§3.1); after the P↑ projections the
kernel-level contract is identical to self-attention, so one kernel serves
both the RevFFN blocks and the standard-transformer baselines.

Schedule: grid = (batch*heads, q_blocks); the K/V scan runs inside the
kernel with an online-softmax accumulator, so only one (block_q, head_dim)
output tile plus one (block_k, head_dim) K/V tile are live at a time —
the HBM↔VMEM schedule a CUDA flash kernel expresses with threadblocks is
expressed here with BlockSpec + fori_loop. ``interpret=True`` always.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool, scale: float,
                 valid_len: int):
    # q_ref: [block_q, hd]; k_ref/v_ref: [S, hd]; o_ref: [block_q, hd]
    block_q, hd = q_ref.shape
    s = k_ref.shape[0]
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    q_offs = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    n_kb = s // block_k

    def body(kb, carry):
        acc, m_i, l_i = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], kb * block_k, block_k, 0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], kb * block_k, block_k, 0).astype(jnp.float32)
        logits = q @ k.T  # [block_q, block_k]
        k_offs = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = k_offs[None, :] < valid_len  # drop padded key positions
        if causal:
            mask = mask & (q_offs[:, None] >= k_offs[None, :])
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, n_kb, body, (acc0, m0, l0))
    l_i = jnp.where(l_i == 0.0, 1.0, l_i)  # fully-masked rows (none under causal)
    o_ref[...] = (acc / l_i[:, None]).astype(o_ref.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              block_q: int = 64, block_k: int = 64) -> jax.Array:
    """q,k,v: [B, H, S, hd] (GQA: K/V heads repeated up-front). Matches
    ref.attention."""
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    bq = min(block_q, s)
    bk = min(block_k, s)
    # pad sequence to lcm of the blocks
    pad = max((-s) % bq, (-s) % bk)
    # simpler: pad to multiple of both
    target = s
    while target % bq or target % bk:
        target += 1
    pad = target - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        s_p = target
    else:
        s_p = s

    qf = q.reshape(b * h, s_p, hd)
    kf = k.reshape(b * h, s_p, hd)
    vf = v.reshape(b * h, s_p, hd)
    scale = 1.0 / float(hd) ** 0.5
    grid = (b * h, s_p // bq)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_k=bk, causal=causal, scale=scale,
                          valid_len=s),
        out_shape=jax.ShapeDtypeStruct((b * h, s_p, hd), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((None, s_p, hd), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((None, s_p, hd), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda bh, qi: (bh, qi, 0)),
        interpret=True,
    )(qf, kf, vf)
    out = out.reshape(b, h, s_p, hd)
    if pad:
        out = out[:, :, :s, :]
    return out
