"""Pallas MoE expert-FFN kernel (dense dispatch, SwiGLU experts).

Grid = (n_experts, f_chunks, token_blocks). Each step loads one expert's
weight *slab* (a `block_f`-wide slice of the gate/up/down matrices) and
one (block_t, d) token tile, computes that slab's SwiGLU contribution,
scales by the expert's combine weights, and accumulates into the output
tile. SwiGLU is elementwise in the hidden axis, so f-chunking is exact:

    y = Σ_f  (silu(x @ Wg[:, f]) * (x @ Wu[:, f])) @ Wd[f, :]

The output BlockSpec ignores the expert and chunk axes, so successive
steps revisit the same tile — the canonical Pallas accumulation pattern
(`@pl.when(first step)` zero-init, then `+=`).

**Why f-chunking (§Perf L1):** at Qwen1.5-MoE-A2.7B geometry a full
expert tile is 3·d·f·2B ≈ 17.3 MB — over the ~16 MB VMEM budget. With
block_f=512 the slab is 6.3 MB, fitting with double-buffering headroom
while keeping the MXU's 128-lane tiles full (see
compile.kernel_analysis). ``interpret=True`` always.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_kernel(x_ref, comb_ref, wg_ref, wu_ref, wd_ref, o_ref):
    e = pl.program_id(0)
    fi = pl.program_id(1)

    @pl.when(jnp.logical_and(e == 0, fi == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)           # [bt, d]
    wg = wg_ref[...].astype(jnp.float32)         # [d, bf]
    wu = wu_ref[...].astype(jnp.float32)
    wd = wd_ref[...].astype(jnp.float32)         # [bf, d]
    w = comb_ref[...].astype(jnp.float32)        # [bt, 1] combine weight, expert e
    h = jax.nn.silu(x @ wg) * (x @ wu)           # [bt, bf]
    y = (h @ wd) * w
    o_ref[...] = o_ref[...] + y.astype(o_ref.dtype)


def moe_ffn(x: jax.Array, combine: jax.Array, w_gate: jax.Array, w_up: jax.Array,
            w_down: jax.Array, block_t: int = 128, block_f: int = 512) -> jax.Array:
    """x: [T, d]; combine: [T, E]; w_gate/w_up: [E, d, f]; w_down: [E, f, d].

    Matches ref.moe_ffn (float32 accumulate, cast on store)."""
    t, d = x.shape
    e, _, f = w_gate.shape
    bt = min(block_t, t)
    bf = min(block_f, f)
    pad_t = (-t) % bt
    if pad_t:
        x = jnp.pad(x, ((0, pad_t), (0, 0)))
        combine = jnp.pad(combine, ((0, pad_t), (0, 0)))
    pad_f = (-f) % bf
    if pad_f:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, pad_f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, pad_f)))
        w_down = jnp.pad(w_down, ((0, 0), (0, pad_f), (0, 0)))
    tp = x.shape[0]
    fp = w_gate.shape[2]
    grid = (e, fp // bf, tp // bt)
    out = pl.pallas_call(
        _moe_kernel,
        out_shape=jax.ShapeDtypeStruct((tp, d), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda ei, fi, ti: (ti, 0)),
            pl.BlockSpec((bt, 1), lambda ei, fi, ti: (ti, ei)),
            pl.BlockSpec((None, d, bf), lambda ei, fi, ti: (ei, 0, fi)),
            pl.BlockSpec((None, d, bf), lambda ei, fi, ti: (ei, 0, fi)),
            pl.BlockSpec((None, bf, d), lambda ei, fi, ti: (ei, fi, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda ei, fi, ti: (ti, 0)),
        interpret=True,
    )(x, combine, w_gate, w_up, w_down)
    if pad_t:
        out = out[:t]
    return out.astype(x.dtype)
