"""L1 Pallas kernels (interpret=True) + pure-jnp oracles.

Every kernel here matches a same-named function in ``ref`` to float32
tolerance; python/tests/test_kernels.py is the enforcement point.
"""

from . import ref  # noqa: F401
from .attention import attention  # noqa: F401
from .coupling import couple_add, couple_sub  # noqa: F401
from .moe_ffn import moe_ffn  # noqa: F401
from .rmsnorm import rmsnorm  # noqa: F401
from .router import router_topk  # noqa: F401
