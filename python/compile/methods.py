"""Fine-tuning method registry — every row of the paper's Tables 1/2.

A ``MethodSpec`` bundles, for one method:
  * parameter initialisation (base model + any PEFT tensors),
  * the forward function,
  * which flat tensor paths are trainable,
  * the optimizer family and its state shapes,
  * weight-decay mask.

Methods:
  sft      — full-parameter AdamW on the standard model, jax.remat
             per layer ('SFT + Activation Checkpointing').
  lora     — rank-r adapters on Wq/Wk/Wv/Wo, base frozen [10].
  dora     — LoRA + magnitude/direction decomposition [19].
  ia3      — learned rescaling of K, V and shared-expert FFN [20].
  lomo     — full-parameter fused-SGD memory profile [22].
  galore   — full-parameter AdamW with rank-r gradient projection [23].
  revffn   — the paper: reversible model, O(1)-activation backward;
             stage 1 trains adapters+stream norms, stage 2 everything
             except MoE routers (§3.3).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from . import params as P
from .configs import ModelConfig, TrainConfig
from .model import revffn_forward, standard_forward

METHODS = ["sft", "lora", "dora", "ia3", "lomo", "galore", "revffn"]
# revffn_naive: identical math without the O(1)-activation custom VJP —
# the memory-calibration upper bound, not a Table 1/2 row.
ALL_VARIANTS = METHODS + ["revffn_naive"]


@dataclass
class MethodSpec:
    name: str
    init: Callable          # (key, ModelConfig) -> params dict
    forward: Callable       # (params, tokens) -> (logits, aux)
    trainable: Callable     # (flat path str) -> bool
    optimizer: str          # adamw | sgd | galore
    router_aux: bool        # add load-balance aux to the loss?


def _no_decay(path: str) -> bool:
    """Norm gains, biases and 1-D vectors take no weight decay."""
    leaf = path.rsplit(".", 1)[-1]
    return leaf.startswith("norm") or leaf in ("lk", "lv", "lff") or "gate" in leaf


def decay_mask(paths: list[str], shapes: list[tuple]) -> list[bool]:
    return [not _no_decay(p) and len(s) >= 2 for p, s in zip(paths, shapes)]


# ---------------------------------------------------------------------------
# PEFT parameter initialisers (stacked per layer for lax.scan)
# ---------------------------------------------------------------------------

def _init_lora_layer(key, cfg: ModelConfig, rank: int) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    dkv = cfg.n_kv_heads * cfg.head_dim
    out = {}
    for k_, (name, dout) in zip(ks, [("wq", d), ("wk", dkv), ("wv", dkv), ("wo", d)]):
        out[f"{name}_a"] = jax.random.normal(k_, (d, rank), jnp.float32) / jnp.sqrt(rank)
        out[f"{name}_b"] = jnp.zeros((rank, dout), jnp.float32)
    return out


def init_lora(key, cfg: ModelConfig, rank: int) -> dict:
    ks = jax.random.split(key, cfg.n_layers)
    layers = [_init_lora_layer(ks[i], cfg, rank) for i in range(cfg.n_layers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *layers)


def init_dora(base: dict, cfg: ModelConfig) -> dict:
    """Magnitude vectors initialised to the pre-trained column norms."""
    out = {}
    for name in ("wq", "wk", "wv", "wo"):
        w = base["layers"]["attn"][name]  # [L, d, dout]
        out[f"m_{name}"] = jnp.linalg.norm(w, axis=1)  # [L, dout]
    return out


def init_ia3(cfg: ModelConfig) -> dict:
    dkv = cfg.n_kv_heads * cfg.head_dim
    l = cfg.n_layers
    return {
        "lk": jnp.ones((l, dkv), jnp.float32),
        "lv": jnp.ones((l, dkv), jnp.float32),
        "lff": jnp.ones((l, cfg.d_ff_shared), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------

def get_method(name: str, cfg: ModelConfig, tc: TrainConfig,
               use_pallas: bool = False) -> MethodSpec:
    cfg.validate()
    lora_scale = tc.lora_alpha / tc.lora_rank

    if name == "sft":
        return MethodSpec(
            name=name,
            init=lambda key, c=cfg: P.init_standard_model(key, c),
            forward=lambda p, t: standard_forward(p, t, cfg, use_pallas, remat=True),
            trainable=lambda path: True,
            optimizer="adamw",
            router_aux=True,
        )

    if name == "lomo":
        return MethodSpec(
            name=name,
            init=lambda key, c=cfg: P.init_standard_model(key, c),
            forward=lambda p, t: standard_forward(p, t, cfg, use_pallas, remat=True),
            trainable=lambda path: True,
            optimizer="sgd",
            router_aux=True,
        )

    if name == "galore":
        return MethodSpec(
            name=name,
            init=lambda key, c=cfg: P.init_standard_model(key, c),
            forward=lambda p, t: standard_forward(p, t, cfg, use_pallas, remat=True),
            trainable=lambda path: True,
            optimizer="galore",
            router_aux=True,
        )

    if name == "lora":
        def init(key, c=cfg):
            k1, k2 = jax.random.split(key)
            return {"base": P.init_standard_model(k1, c),
                    "peft": {"lora": init_lora(k2, c, tc.lora_rank)}}

        return MethodSpec(
            name=name,
            init=init,
            forward=lambda p, t: standard_forward(
                p["base"], t, cfg, use_pallas, remat=False,
                adapters_stacked=p["peft"], lora_scale=lora_scale,
                freeze_router=True),
            trainable=lambda path: path.startswith("peft."),
            optimizer="adamw",
            router_aux=False,
        )

    if name == "dora":
        def init(key, c=cfg):
            k1, k2 = jax.random.split(key)
            base = P.init_standard_model(k1, c)
            return {"base": base,
                    "peft": {"lora": init_lora(k2, c, tc.lora_rank),
                             "dora": init_dora(base, c)}}

        return MethodSpec(
            name=name,
            init=init,
            forward=lambda p, t: standard_forward(
                p["base"], t, cfg, use_pallas, remat=False,
                adapters_stacked=p["peft"], lora_scale=lora_scale,
                freeze_router=True),
            trainable=lambda path: path.startswith("peft."),
            optimizer="adamw",
            router_aux=False,
        )

    if name == "ia3":
        def init(key, c=cfg):
            return {"base": P.init_standard_model(key, c),
                    "peft": {"ia3": init_ia3(c)}}

        return MethodSpec(
            name=name,
            init=init,
            forward=lambda p, t: standard_forward(
                p["base"], t, cfg, use_pallas, remat=False,
                adapters_stacked=p["peft"], freeze_router=True),
            trainable=lambda path: path.startswith("peft."),
            optimizer="adamw",
            router_aux=False,
        )

    if name in ("revffn", "revffn_naive"):
        stage = tc.stage
        reversible_bwd = name == "revffn"

        def trainable(path: str) -> bool:
            if ".moe.router" in path:
                return False          # routers frozen in both stages (§3.3)
            if stage == 1:
                return (".adapters." in path or ".norm_x1" in path
                        or ".norm_x2" in path or ".norm_y1" in path)
            return True

        return MethodSpec(
            name=name,
            init=lambda key, c=cfg: P.init_rev_model(key, c),
            forward=lambda p, t: revffn_forward(p, t, cfg, use_pallas,
                                                reversible_bwd=reversible_bwd),
            trainable=trainable,
            optimizer="adamw",
            router_aux=False,  # routers frozen: aux is a metric only
        )

    raise ValueError(f"unknown method {name!r}; expected one of {METHODS}")
