"""AOT pipeline tests: HLO text emission, manifest consistency, and
parameter-blob layout (checked against artifacts/ when present)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import to_hlo_text
from compile.configs import CONFIGS, ModelConfig, TrainConfig
from compile.params import (
    flatten_params,
    init_standard_model,
    manifest_entries,
    write_param_blob,
)

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")


def tiny_cfg():
    return ModelConfig(
        name="t", vocab_size=64, d_model=32, n_layers=1, n_heads=2, n_kv_heads=2,
        n_experts=2, top_k=1, d_ff_expert=16, d_ff_shared=16, max_seq_len=8,
    )


def test_to_hlo_text_emits_parseable_module():
    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_hlo_text_has_no_topk_op():
    """xla_extension 0.5.1's parser rejects the TopK custom attribute —
    the router must lower to argmax-extraction ops only."""
    from compile.kernels import ref

    def fn(logits):
        c, aux = ref.router_topk(logits, 2)
        return (c, aux)

    spec = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec))
    assert " topk(" not in text, "TopK HLO op would break the pinned parser"


def test_param_blob_roundtrip(tmp_path):
    cfg = tiny_cfg()
    params = init_standard_model(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "p.bin")
    total = write_param_blob(params, path)
    entries = manifest_entries(params)
    assert total == sum(e["nbytes"] for e in entries)
    blob = open(path, "rb").read()
    # spot-check: every entry's bytes decode to the right tensor
    flat = dict(flatten_params(params))
    for e in entries:
        raw = blob[e["offset"]:e["offset"] + e["nbytes"]]
        arr = np.frombuffer(raw, dtype="<f4").reshape(e["shape"] or (1,))
        want = np.asarray(flat[e["name"]], dtype=np.float32).reshape(e["shape"] or (1,))
        np.testing.assert_array_equal(arr, want)


def test_manifest_offsets_contiguous():
    cfg = tiny_cfg()
    params = init_standard_model(jax.random.PRNGKey(0), cfg)
    entries = manifest_entries(params)
    offset = 0
    for e in entries:
        assert e["offset"] == offset
        offset += e["nbytes"]


def test_named_configs_validate():
    for name, cfg in CONFIGS.items():
        cfg.validate()
        assert cfg.name == name


@pytest.mark.skipif(not os.path.isdir(ART), reason="run `make artifacts` first")
def test_built_artifacts_manifest_consistency():
    index = json.load(open(os.path.join(ART, "index.json")))
    for variant in index["variants"]:
        mpath = os.path.join(ART, variant, "manifest.json")
        m = json.load(open(mpath))
        io = m["io"]
        assert io["n_params"] == len(m["tensors"]), variant
        assert len(io["trainable"]) == len(m["tensors"]), variant
        assert len(io["opt_shapes"]) == io["n_opt"], variant
        for kind, rel in m["artifacts"].items():
            assert os.path.exists(os.path.join(ART, variant, rel)), (variant, kind)
        # blob coverage
        for t in m["tensors"]:
            blob = os.path.join(ART, "blobs", f"{t['blob']}.bin")
            assert os.path.getsize(blob) >= t["offset"] + t["nbytes"], t["name"]


@pytest.mark.skipif(not os.path.isdir(ART), reason="run `make artifacts` first")
def test_built_artifacts_trainable_counts():
    """PEFT methods train ≲2% of params; full-FT methods ≳95%."""
    def frac(variant):
        m = json.load(open(os.path.join(ART, variant, "manifest.json")))
        return m["n_params_trainable"] / m["n_params_total"]

    for peft in ("lora", "dora", "ia3"):
        assert frac(peft) < 0.05, peft
    for full in ("sft", "lomo", "galore", "revffn_stage2"):
        assert frac(full) > 0.9, full
    # stage 1: adapters only — a small but non-trivial slice
    assert 0.001 < frac("revffn_stage1") < 0.2
