"""Method/optimizer-level tests: every Table-1/2 method's train step must
decrease loss, respect its trainable mask, and keep frozen tensors
bit-identical; optimizers must match hand-computed updates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim
from compile.configs import ModelConfig, TrainConfig
from compile.methods import METHODS
from compile.params import flatten_params
from compile.trainstep import StepBuilder


def tiny_cfg():
    return ModelConfig(
        name="t", vocab_size=64, d_model=32, n_layers=2, n_heads=2, n_kv_heads=2,
        n_experts=4, top_k=2, d_ff_expert=24, d_ff_shared=48, max_seq_len=16,
    )


def batch():
    tok = (jnp.arange(32, dtype=jnp.int32).reshape(2, 16) * 3) % 64
    tgt = jnp.roll(tok, -1, axis=1)
    msk = jnp.ones((2, 16), jnp.float32)
    return tok, tgt, msk


def run_steps(method, n=3, stage=2):
    cfg = tiny_cfg()
    tc = TrainConfig(method=method, batch_size=2, seq_len=16, stage=stage, lr=1e-3)
    sb = StepBuilder(method, cfg, tc)
    params = [l for _, l in flatten_params(sb.params)]
    m = [jnp.zeros(s, jnp.float32) for s in sb.opt_shapes]
    v = [jnp.zeros(s, jnp.float32) for s in sb.opt_shapes]
    tok, tgt, msk = batch()
    step_fn = jax.jit(sb.train_step)
    losses = []
    for i in range(n):
        params, m, v, loss, gnorm, aux = step_fn(
            params, m, v, tok, tgt, msk, jnp.float32(1e-3), jnp.float32(i + 1)
        )
        losses.append(float(loss))
    return sb, params, losses


@pytest.mark.parametrize("method", METHODS)
def test_loss_decreases(method):
    _, _, losses = run_steps(method, n=3)
    assert losses[-1] < losses[0], f"{method}: {losses}"
    assert all(np.isfinite(l) for l in losses)


@pytest.mark.parametrize("method", METHODS)
def test_frozen_tensors_unchanged(method):
    cfg = tiny_cfg()
    tc = TrainConfig(method=method, batch_size=2, seq_len=16, lr=1e-2)
    sb = StepBuilder(method, cfg, tc)
    before = [np.asarray(l) for _, l in flatten_params(sb.params)]
    sb2, after, _ = run_steps(method, n=2)
    changed_frozen = []
    unchanged_trainable = 0
    for i, (b, a, tr, path) in enumerate(
        zip(before, after, sb.trainable, sb.paths)
    ):
        same = np.array_equal(b, np.asarray(a))
        if not tr and not same:
            changed_frozen.append(path)
        if tr and same:
            unchanged_trainable += 1
    assert not changed_frozen, f"{method}: frozen tensors changed: {changed_frozen}"
    # at least 80% of trainable tensors actually moved
    n_train = sum(sb.trainable)
    assert unchanged_trainable <= max(1, n_train // 5), (
        f"{method}: {unchanged_trainable}/{n_train} trainable tensors never moved"
    )


def test_revffn_stage1_trains_only_adapters():
    cfg = tiny_cfg()
    tc = TrainConfig(method="revffn", batch_size=2, seq_len=16, stage=1)
    sb = StepBuilder("revffn", cfg, tc)
    for path, tr in zip(sb.paths, sb.trainable):
        expected = (
            ".adapters." in path
            or ".norm_x1" in path
            or ".norm_x2" in path
            or ".norm_y1" in path
        )
        assert tr == expected, f"stage1 flag wrong for {path}"


def test_revffn_router_frozen_both_stages():
    cfg = tiny_cfg()
    for stage in (1, 2):
        tc = TrainConfig(method="revffn", batch_size=2, seq_len=16, stage=stage)
        sb = StepBuilder("revffn", cfg, tc)
        for path, tr in zip(sb.paths, sb.trainable):
            if ".moe.router" in path:
                assert not tr


def test_lomo_has_no_optimizer_state():
    cfg = tiny_cfg()
    tc = TrainConfig(method="lomo", batch_size=2, seq_len=16)
    sb = StepBuilder("lomo", cfg, tc)
    assert sb.opt_shapes == []


def test_galore_moment_shapes_rank_reduced():
    cfg = tiny_cfg()
    tc = TrainConfig(method="galore", batch_size=2, seq_len=16, galore_rank=4)
    sb = StepBuilder("galore", cfg, tc)
    # embed is [64, 32] -> moments [4, 64]
    i = sb.paths.index("embed")
    ti = sb.t_idx.index(i)
    assert sb.opt_shapes[ti] == (4, 64)


# ---------------------------------------------------------------------------
# Optimizer unit tests (hand-computed)
# ---------------------------------------------------------------------------

def test_adamw_first_step_matches_hand_calc():
    tc = TrainConfig()
    p = [jnp.array([1.0, -2.0])]
    g = [jnp.array([0.5, 0.5])]
    m = [jnp.zeros(2)]
    v = [jnp.zeros(2)]
    new_p, new_m, new_v = optim.adamw_update(
        p, g, m, v, jnp.float32(0.1), jnp.float32(1.0), tc, [False]
    )
    # bias-corrected first step: update = g/|g| = sign(g) (approx, eps small)
    np.testing.assert_allclose(new_p[0], p[0] - 0.1 * np.sign(g[0]), rtol=1e-4)
    np.testing.assert_allclose(new_m[0], 0.1 * np.asarray(g[0]), rtol=1e-6)


def test_adamw_weight_decay_applied_only_when_masked():
    tc = TrainConfig(weight_decay=0.5)
    p = [jnp.array([1.0])]
    g = [jnp.array([0.0])]
    m = [jnp.zeros(1)]
    v = [jnp.zeros(1)]
    decayed, _, _ = optim.adamw_update(
        p, g, m, v, jnp.float32(0.1), jnp.float32(1.0), tc, [True]
    )
    kept, _, _ = optim.adamw_update(
        p, g, m, v, jnp.float32(0.1), jnp.float32(1.0), tc, [False]
    )
    assert float(decayed[0][0]) < 1.0
    np.testing.assert_allclose(kept[0], 1.0, atol=1e-6)


def test_sgd_update_exact():
    tc = TrainConfig()
    p = [jnp.array([1.0, 2.0])]
    g = [jnp.array([0.5, -1.0])]
    out = optim.sgd_update(p, g, jnp.float32(0.1), tc)
    np.testing.assert_allclose(out[0], [0.95, 2.1], rtol=1e-6)


def test_clip_by_global_norm():
    g = [jnp.array([3.0, 4.0])]  # norm 5
    clipped, gn = optim.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), 5.0, rtol=1e-5)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped[0])), 1.0, rtol=1e-4
    )
    # under the limit: unchanged
    same, _ = optim.clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(same[0], g[0], rtol=1e-6)


def test_galore_projection_refresh_changes_with_epoch():
    shape = (16, 32)
    p0 = optim._galore_proj(shape, 4, jnp.int32(0), 7, update_every=10)
    p_same = optim._galore_proj(shape, 4, jnp.int32(9), 7, update_every=10)
    p_new = optim._galore_proj(shape, 4, jnp.int32(10), 7, update_every=10)
    np.testing.assert_allclose(p0, p_same, rtol=1e-6)
    assert float(jnp.max(jnp.abs(p0 - p_new))) > 1e-3


def test_galore_nonmatrix_tensors_get_plain_adamw():
    tc = TrainConfig(galore_rank=4)
    p = [jnp.ones((8,))]
    g = [jnp.full((8,), 0.1)]
    m = [jnp.zeros((8,))]
    v = [jnp.zeros((8,))]
    gal_p, _, _ = optim.galore_update(
        p, g, m, v, jnp.float32(0.1), jnp.float32(1.0), tc, [False]
    )
    ad_p, _, _ = optim.adamw_update(
        p, g, m, v, jnp.float32(0.1), jnp.float32(1.0), tc, [False]
    )
    np.testing.assert_allclose(gal_p[0], ad_p[0], rtol=1e-6)
