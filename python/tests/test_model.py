"""L2 model-level tests: shapes, pallas/ref path equivalence, causality,
loss masking, and adapter behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import ModelConfig, TrainConfig
from compile.methods import get_method, init_lora
from compile.model import lm_loss, revffn_forward, standard_forward
from compile.params import (
    count_params,
    flatten_params,
    init_rev_model,
    init_standard_model,
    rev_model_from_standard,
    unflatten_params,
)


def tiny_cfg(**kw):
    base = dict(
        name="t", vocab_size=64, d_model=32, n_layers=2, n_heads=2, n_kv_heads=2,
        n_experts=4, top_k=2, d_ff_expert=24, d_ff_shared=48, max_seq_len=16,
    )
    base.update(kw)
    return ModelConfig(**base)


CFG = tiny_cfg()
KEY = jax.random.PRNGKey(0)
TOKENS = (jnp.arange(32, dtype=jnp.int32).reshape(2, 16) * 5) % CFG.vocab_size


def test_standard_forward_shapes():
    params = init_standard_model(KEY, CFG)
    logits, aux = standard_forward(params, TOKENS, CFG)
    assert logits.shape == (2, 16, CFG.vocab_size)
    assert jnp.isfinite(logits).all()
    assert float(aux) > 0.0  # load-balance stat is positive


def test_revffn_forward_shapes():
    params = init_rev_model(KEY, CFG)
    logits, _ = revffn_forward(params, TOKENS, CFG)
    assert logits.shape == (2, 16, CFG.vocab_size)
    assert jnp.isfinite(logits).all()


def test_remat_does_not_change_forward():
    params = init_standard_model(KEY, CFG)
    l1, _ = standard_forward(params, TOKENS, CFG, remat=False)
    l2, _ = standard_forward(params, TOKENS, CFG, remat=True)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)


def test_pallas_path_matches_ref_path_standard():
    params = init_standard_model(KEY, CFG)
    l_ref, _ = standard_forward(params, TOKENS, CFG, use_pallas=False)
    l_pl, _ = standard_forward(params, TOKENS, CFG, use_pallas=True)
    np.testing.assert_allclose(l_pl, l_ref, rtol=5e-4, atol=5e-4)


def test_pallas_path_matches_ref_path_revffn():
    params = init_rev_model(KEY, CFG)
    l_ref, _ = revffn_forward(params, TOKENS, CFG, use_pallas=False)
    l_pl, _ = revffn_forward(params, TOKENS, CFG, use_pallas=True)
    np.testing.assert_allclose(l_pl, l_ref, rtol=5e-4, atol=5e-4)


def test_causality_of_full_model():
    """Changing a later token must not affect earlier logits."""
    params = init_standard_model(KEY, CFG)
    l1, _ = standard_forward(params, TOKENS, CFG)
    toks2 = TOKENS.at[:, -1].set((TOKENS[:, -1] + 3) % CFG.vocab_size)
    l2, _ = standard_forward(params, toks2, CFG)
    np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5, atol=1e-5)


def test_revffn_causality():
    params = init_rev_model(KEY, CFG)
    l1, _ = revffn_forward(params, TOKENS, CFG)
    toks2 = TOKENS.at[:, -1].set((TOKENS[:, -1] + 3) % CFG.vocab_size)
    l2, _ = revffn_forward(params, toks2, CFG)
    np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5, atol=1e-5)


def test_lm_loss_masking():
    logits = jnp.zeros((1, 4, 8), jnp.float32)
    targets = jnp.zeros((1, 4), jnp.int32)
    full = lm_loss(logits, targets, jnp.ones((1, 4)))
    half = lm_loss(logits, targets, jnp.array([[1.0, 1.0, 0.0, 0.0]]))
    # uniform logits: per-token loss = log(V) regardless of mask count
    np.testing.assert_allclose(full, np.log(8), rtol=1e-6)
    np.testing.assert_allclose(half, np.log(8), rtol=1e-6)
    # all-masked: returns 0 (defensive denom)
    zero = lm_loss(logits, targets, jnp.zeros((1, 4)))
    assert float(zero) == 0.0


def test_lm_loss_label_smoothing_increases_uniform_optimal():
    key = jax.random.PRNGKey(1)
    logits = jax.random.normal(key, (2, 8, 16))
    targets = jnp.zeros((2, 8), jnp.int32)
    mask = jnp.ones((2, 8))
    base = lm_loss(logits, targets, mask, label_smoothing=0.0)
    smooth = lm_loss(logits, targets, mask, label_smoothing=0.1)
    assert float(smooth) != float(base)


def test_rev_model_wraps_standard_weights():
    std = init_standard_model(KEY, CFG)
    rev = rev_model_from_standard(std, jax.random.PRNGKey(1), CFG)
    np.testing.assert_array_equal(rev["embed"], std["embed"])
    np.testing.assert_array_equal(
        rev["layers"]["attn"]["wq"], std["layers"]["attn"]["wq"]
    )
    np.testing.assert_array_equal(
        rev["layers"]["moe"]["wg"], std["layers"]["moe"]["wg"]
    )


def test_adapter_init_near_duplicate_map():
    """P↑ starts near [I;I] so the wrapped block initially sees a
    duplicated half-stream (keeps stage-1 warm-up short)."""
    rev = init_rev_model(KEY, CFG)
    up = rev["layers"]["adapters"]["attn_up_q"][0]  # [dh, d]
    dh = CFG.d_half
    eye2 = np.concatenate([np.eye(dh), np.eye(dh)], axis=1)
    assert float(jnp.max(jnp.abs(up - eye2))) < 0.1


def test_flatten_unflatten_roundtrip():
    params = init_rev_model(KEY, CFG)
    flat = flatten_params(params)
    back = unflatten_params(flat)
    flat2 = flatten_params(back)
    assert [p for p, _ in flat] == [p for p, _ in flat2]
    for (_, a), (_, b) in zip(flat, flat2):
        np.testing.assert_array_equal(a, b)


def test_flatten_order_is_sorted_and_deterministic():
    params = init_standard_model(KEY, CFG)
    paths = [p for p, _ in flatten_params(params)]
    assert paths == sorted(paths)


def test_param_counts_scale_with_config():
    small = count_params(init_standard_model(KEY, tiny_cfg(n_layers=1)))
    big = count_params(init_standard_model(KEY, tiny_cfg(n_layers=4)))
    assert big > small


def test_lora_changes_logits_only_after_b_nonzero():
    """LoRA B=0 init: forward must equal the base model at t=0."""
    cfg = CFG
    tc = TrainConfig(method="lora", batch_size=2, seq_len=16)
    spec = get_method("lora", cfg, tc)
    params = spec.init(KEY)
    base_logits, _ = standard_forward(params["base"], TOKENS, cfg, freeze_router=True)
    lora_logits, _ = spec.forward(params, TOKENS)
    np.testing.assert_allclose(lora_logits, base_logits, rtol=1e-5, atol=1e-5)
    # perturb B: logits must now differ
    params["peft"]["lora"]["wq_b"] = params["peft"]["lora"]["wq_b"] + 0.1
    lora2, _ = spec.forward(params, TOKENS)
    assert float(jnp.max(jnp.abs(lora2 - base_logits))) > 1e-4


def test_ia3_identity_at_init():
    tc = TrainConfig(method="ia3", batch_size=2, seq_len=16)
    spec = get_method("ia3", CFG, tc)
    params = spec.init(KEY)
    base_logits, _ = standard_forward(params["base"], TOKENS, CFG, freeze_router=True)
    ia3_logits, _ = spec.forward(params, TOKENS)
    np.testing.assert_allclose(ia3_logits, base_logits, rtol=1e-5, atol=1e-5)
