"""Kernel-vs-oracle correctness: the CORE L1 signal.

Each Pallas kernel (interpret=True) must match its pure-jnp oracle in
``compile.kernels.ref``. Hypothesis sweeps shapes/dtypes; fixed seeds keep
the suite deterministic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# CI installs hypothesis; environments without it (minimal containers)
# skip the property sweeps instead of failing collection for the suite.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 70),
    d=st.sampled_from([8, 16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_matches_ref(rows, d, seed):
    r = rng(seed)
    x = jnp.asarray(r.normal(size=(rows, d)), jnp.float32)
    gamma = jnp.asarray(r.normal(size=(d,)), jnp.float32)
    got = kernels.rmsnorm(x, gamma)
    want = ref.rmsnorm(x, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(2, 3, 16), (1, 1, 8), (4, 64, 128)])
def test_rmsnorm_nd_shapes(shape):
    r = rng(0)
    x = jnp.asarray(r.normal(size=shape), jnp.float32)
    gamma = jnp.asarray(r.normal(size=(shape[-1],)), jnp.float32)
    np.testing.assert_allclose(
        kernels.rmsnorm(x, gamma), ref.rmsnorm(x, gamma), rtol=1e-5, atol=1e-5
    )


def test_rmsnorm_bf16_dtype_preserved():
    r = rng(1)
    x = jnp.asarray(r.normal(size=(8, 16)), jnp.bfloat16)
    gamma = jnp.ones((16,), jnp.bfloat16)
    out = kernels.rmsnorm(x, gamma)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.rmsnorm(x, gamma).astype(jnp.float32),
        rtol=2e-2, atol=2e-2,
    )


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([4, 16, 33, 64]),
    hd=st.sampled_from([8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, s, hd, causal, seed):
    r = rng(seed)
    q = jnp.asarray(r.normal(size=(b, h, s, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, h, s, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, h, s, hd)), jnp.float32)
    got = kernels.attention(q, k, v, causal=causal, block_q=16, block_k=16)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_attention_gqa_head_repeat():
    r = rng(7)
    b, h, hkv, s, hd = 2, 4, 2, 32, 8
    q = jnp.asarray(r.normal(size=(b, h, s, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, hkv, s, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, hkv, s, hd)), jnp.float32)
    got = kernels.attention(q, k, v, block_q=16, block_k=16)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_attention_causality():
    """Perturbing a future K/V position must not change earlier outputs."""
    r = rng(3)
    b, h, s, hd = 1, 2, 16, 8
    q = jnp.asarray(r.normal(size=(b, h, s, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, h, s, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, h, s, hd)), jnp.float32)
    out1 = kernels.attention(q, k, v, block_q=8, block_k=8)
    k2 = k.at[:, :, -1].add(100.0)
    v2 = v.at[:, :, -1].add(100.0)
    out2 = kernels.attention(q, k2, v2, block_q=8, block_k=8)
    np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(1, 90),
    e=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_router_matches_ref(t, e, k, seed):
    k = min(k, e)
    r = rng(seed)
    logits = jnp.asarray(r.normal(size=(t, e)) * 2.0, jnp.float32)
    got_c, got_aux = kernels.router_topk(logits, k)
    want_c, want_aux = ref.router_topk(logits, k)
    np.testing.assert_allclose(got_c, want_c, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_aux, want_aux, rtol=1e-5, atol=1e-6)


def test_router_combine_rows_sum_to_one():
    r = rng(11)
    logits = jnp.asarray(r.normal(size=(40, 8)), jnp.float32)
    combine, _ = kernels.router_topk(logits, 2)
    np.testing.assert_allclose(np.sum(np.asarray(combine), axis=-1), 1.0, rtol=1e-5)
    assert (np.sum(np.asarray(combine) > 0, axis=-1) == 2).all()


def test_router_topk_equals_experts_is_softmax():
    r = rng(12)
    logits = jnp.asarray(r.normal(size=(10, 4)), jnp.float32)
    combine, _ = kernels.router_topk(logits, 4, renormalize=False)
    np.testing.assert_allclose(
        combine, jax.nn.softmax(logits, axis=-1), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# MoE FFN
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    t=st.sampled_from([1, 7, 32, 65]),
    d=st.sampled_from([8, 16]),
    e=st.sampled_from([2, 4]),
    f=st.sampled_from([12, 24]),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_ffn_matches_ref(t, d, e, f, seed):
    r = rng(seed)
    x = jnp.asarray(r.normal(size=(t, d)) * 0.5, jnp.float32)
    logits = jnp.asarray(r.normal(size=(t, e)), jnp.float32)
    combine, _ = ref.router_topk(logits, min(2, e))
    wg = jnp.asarray(r.normal(size=(e, d, f)) * 0.1, jnp.float32)
    wu = jnp.asarray(r.normal(size=(e, d, f)) * 0.1, jnp.float32)
    wd = jnp.asarray(r.normal(size=(e, f, d)) * 0.1, jnp.float32)
    got = kernels.moe_ffn(x, combine, wg, wu, wd, block_t=32, block_f=8)
    want = ref.moe_ffn(x, combine, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-5)
    # unchunked path must agree as well
    got2 = kernels.moe_ffn(x, combine, wg, wu, wd, block_t=32, block_f=f)
    np.testing.assert_allclose(got2, want, rtol=5e-4, atol=5e-5)


def test_moe_ffn_single_expert_equals_swiglu():
    r = rng(5)
    t, d, f = 16, 8, 12
    x = jnp.asarray(r.normal(size=(t, d)), jnp.float32)
    combine = jnp.ones((t, 1), jnp.float32)
    wg = jnp.asarray(r.normal(size=(1, d, f)) * 0.2, jnp.float32)
    wu = jnp.asarray(r.normal(size=(1, d, f)) * 0.2, jnp.float32)
    wd = jnp.asarray(r.normal(size=(1, f, d)) * 0.2, jnp.float32)
    got = kernels.moe_ffn(x, combine, wg, wu, wd)
    want = ref.swiglu(x, wg[0], wu[0], wd[0])
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-5)


def test_moe_ffn_zero_combine_gives_zero():
    r = rng(6)
    x = jnp.asarray(r.normal(size=(8, 8)), jnp.float32)
    combine = jnp.zeros((8, 2), jnp.float32)
    wg = jnp.asarray(r.normal(size=(2, 8, 8)), jnp.float32)
    wu = jnp.asarray(r.normal(size=(2, 8, 8)), jnp.float32)
    wd = jnp.asarray(r.normal(size=(2, 8, 8)), jnp.float32)
    out = kernels.moe_ffn(x, combine, wg, wu, wd)
    np.testing.assert_allclose(out, 0.0, atol=1e-7)


# ---------------------------------------------------------------------------
# Coupling
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(1, 130),
    d=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_coupling_add_sub_roundtrip_bitwise(rows, d, seed):
    """x + f - f must be exact for the same kernel — the numerical basis of
    reversible reconstruction."""
    r = rng(seed)
    x = jnp.asarray(r.normal(size=(rows, d)), jnp.float32)
    f = jnp.asarray(r.normal(size=(rows, d)), jnp.float32)
    y = kernels.couple_add(x, f)
    back = kernels.couple_sub(y, f)
    want_y = np.asarray(x) + np.asarray(f)
    np.testing.assert_array_equal(np.asarray(y), want_y)
    # float add/sub of the same value round-trips when no catastrophic
    # cancellation occurs; tolerance covers the one-ulp cases.
    np.testing.assert_allclose(back, x, rtol=1e-6, atol=1e-6)


def test_coupling_3d_shapes():
    r = rng(9)
    x = jnp.asarray(r.normal(size=(2, 5, 8)), jnp.float32)
    f = jnp.asarray(r.normal(size=(2, 5, 8)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(kernels.couple_add(x, f)), np.asarray(x) + np.asarray(f)
    )


# ---------------------------------------------------------------------------
# RoPE (ref-only helper used by both model paths)
# ---------------------------------------------------------------------------

def test_rope_norm_preserving():
    cos, sin = ref.rope_angles(16, 8)
    r = rng(4)
    x = jnp.asarray(r.normal(size=(1, 2, 16, 8)), jnp.float32)
    y = ref.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_position_zero_identity():
    cos, sin = ref.rope_angles(4, 8)
    r = rng(8)
    x = jnp.asarray(r.normal(size=(1, 1, 4, 8)), jnp.float32)
    y = ref.apply_rope(x, cos, sin)
    np.testing.assert_allclose(y[..., 0, :], x[..., 0, :], rtol=1e-6)
