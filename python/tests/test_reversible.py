"""RevFFN reversible-block correctness (§3.1):

* inverse reconstructs inputs to ~fp32 noise with ONE fixed-point
  iteration (the paper's claim);
* the O(1)-activation custom VJP produces the same gradients as plain
  autodiff;
* the symmetric ablation variant is exactly invertible;
* reconstruction error stays flat as depth grows.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import ModelConfig
from compile.model import revffn_forward, revffn_reconstruct
from compile.params import flatten_params, init_rev_model
from compile.reversible import (
    make_rev_stack,
    make_rev_stack_naive,
    rev_block_forward,
    rev_block_inverse,
)
from compile.kernels import ref


def tiny_cfg(**kw):
    base = dict(
        name="t", vocab_size=64, d_model=32, n_layers=3, n_heads=2, n_kv_heads=2,
        n_experts=4, top_k=2, d_ff_expert=24, d_ff_shared=48, max_seq_len=16,
    )
    base.update(kw)
    return ModelConfig(**base)


def setup(cfg, seed=0, b=2, s=8):
    key = jax.random.PRNGKey(seed)
    params = init_rev_model(key, cfg)
    cos, sin = ref.rope_angles(s, cfg.head_dim, cfg.rope_theta)
    k1, k2 = jax.random.split(key)
    x1 = jax.random.normal(k1, (b, s, cfg.d_half), jnp.float32)
    x2 = jax.random.normal(k2, (b, s, cfg.d_half), jnp.float32)
    return params, cos, sin, x1, x2


def layer0(params):
    return jax.tree.map(lambda x: x[0], params["layers"])


def test_single_block_roundtrip_one_iteration():
    cfg = tiny_cfg(rev_fixedpoint_iters=1)
    params, cos, sin, x1, x2 = setup(cfg)
    p = layer0(params)
    y1, y2, _ = rev_block_forward(p, x1, x2, cos, sin, cfg, False)
    x1h, x2h = rev_block_inverse(p, y1, y2, cos, sin, cfg, False)
    np.testing.assert_allclose(x2h, x2, rtol=1e-5, atol=1e-5)
    # one fixed-point iteration: error small but not exactly zero
    err = float(jnp.max(jnp.abs(x1h - x1)))
    assert err < 5e-3, f"x1 reconstruction error too large: {err}"


def test_more_fixedpoint_iterations_reduce_error():
    errs = []
    for iters in (1, 3, 6):
        cfg = tiny_cfg(rev_fixedpoint_iters=iters)
        params, cos, sin, x1, x2 = setup(cfg, seed=1)
        p = layer0(params)
        y1, y2, _ = rev_block_forward(p, x1, x2, cos, sin, cfg, False)
        x1h, _ = rev_block_inverse(p, y1, y2, cos, sin, cfg, False)
        errs.append(float(jnp.max(jnp.abs(x1h - x1))))
    assert errs[1] <= errs[0] and errs[2] <= errs[1], errs
    assert errs[2] < 1e-5, f"fixed point should converge: {errs}"


def test_symmetric_variant_exactly_invertible():
    cfg = tiny_cfg(rev_symmetric=True)
    params, cos, sin, x1, x2 = setup(cfg, seed=2)
    p = layer0(params)
    y1, y2, _ = rev_block_forward(p, x1, x2, cos, sin, cfg, False)
    x1h, x2h = rev_block_inverse(p, y1, y2, cos, sin, cfg, False)
    np.testing.assert_allclose(x1h, x1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(x2h, x2, rtol=1e-5, atol=1e-6)


def test_stack_reconstruction_error_flat_in_depth():
    errs = {}
    for layers in (1, 3, 5):
        cfg = tiny_cfg(n_layers=layers)
        key = jax.random.PRNGKey(3)
        params = init_rev_model(key, cfg)
        tokens = jnp.arange(16, dtype=jnp.int32).reshape(2, 8) % cfg.vocab_size
        errs[layers] = float(revffn_reconstruct(params, tokens, cfg, False))
    # error should not explode with depth (allow growth within an order)
    assert errs[5] < max(errs[1], 1e-6) * 50, errs
    assert errs[5] < 1e-2


def test_custom_vjp_matches_naive_gradients():
    cfg = tiny_cfg()
    params, cos, sin, x1, x2 = setup(cfg, seed=4)
    sp = params["layers"]
    rev = make_rev_stack(cfg, False)
    naive = make_rev_stack_naive(cfg, False)

    def loss_rev(sp, x1, x2):
        y1, y2, _ = rev(sp, x1, x2, cos, sin)
        return jnp.sum(jnp.square(y1)) + jnp.sum(y2 * x1)

    def loss_naive(sp, x1, x2):
        y1, y2, _ = naive(sp, x1, x2, cos, sin)
        return jnp.sum(jnp.square(y1)) + jnp.sum(y2 * x1)

    g_rev = jax.grad(loss_rev, argnums=(0, 1, 2))(sp, x1, x2)
    g_naive = jax.grad(loss_naive, argnums=(0, 1, 2))(sp, x1, x2)
    # parameter grads
    flat_rev = flatten_params(g_rev[0])
    flat_naive = flatten_params(g_naive[0])
    for (name, a), (_, b) in zip(flat_rev, flat_naive):
        np.testing.assert_allclose(
            a, b, rtol=2e-3, atol=2e-4,
            err_msg=f"param grad mismatch: {name}",
        )
    # input grads
    np.testing.assert_allclose(g_rev[1], g_naive[1], rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(g_rev[2], g_naive[2], rtol=2e-3, atol=2e-4)


def test_forward_outputs_match_between_vjp_modes():
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(5)
    params = init_rev_model(key, cfg)
    tokens = (jnp.arange(16, dtype=jnp.int32).reshape(2, 8) * 7) % cfg.vocab_size
    lr, _ = revffn_forward(params, tokens, cfg, False, reversible_bwd=True)
    ln, _ = revffn_forward(params, tokens, cfg, False, reversible_bwd=False)
    np.testing.assert_allclose(lr, ln, rtol=1e-5, atol=1e-5)


def test_router_gradient_blocked_by_freeze():
    """No gradient may reach the router tensors through the rev stack."""
    cfg = tiny_cfg()
    params, cos, sin, x1, x2 = setup(cfg, seed=6)
    sp = params["layers"]
    rev = make_rev_stack(cfg, False)

    def loss(sp):
        y1, y2, _ = rev(sp, x1, x2, cos, sin)
        return jnp.sum(jnp.square(y1)) + jnp.sum(jnp.square(y2))

    g = jax.grad(loss)(sp)
    np.testing.assert_allclose(g["moe"]["router"], 0.0, atol=1e-8)
    # but expert weights do receive gradient
    assert float(jnp.max(jnp.abs(g["moe"]["wg"]))) > 0.0
